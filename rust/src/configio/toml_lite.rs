//! TOML-lite: the subset of TOML used by scenario files.
//!
//! Supported: `[table]` headers (one level), `key = value` entries with
//! strings (`"..."`), integers, floats, booleans and homogeneous arrays,
//! `#` comments, blank lines. Unsupported TOML (nested tables, dates,
//! multi-line strings) is a parse error — scenarios do not need it.

use std::collections::BTreeMap;

/// One parsed TOML-lite value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(xs) => Some(xs),
            _ => None,
        }
    }
}

/// A parsed document: `tables[""]` holds top-level keys.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub tables: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document; errors carry the 1-based line number.
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        doc.tables.entry(current.clone()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    return Err(format!("line {}: bad table name", lineno + 1));
                }
                current = name.to_string();
                doc.tables.entry(current.clone()).or_default();
            } else {
                let eq = line
                    .find('=')
                    .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(format!("line {}: empty key", lineno + 1));
                }
                let val = parse_value(line[eq + 1..].trim())
                    .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
                doc.tables
                    .get_mut(&current)
                    .unwrap()
                    .insert(key.to_string(), val);
            }
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: &std::path::Path) -> Result<TomlDoc, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        TomlDoc::parse(&text)
    }

    /// Lookup `table.key`.
    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        self.tables.get(table)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if body.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(TomlValue::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if body.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let mut xs = Vec::new();
        for part in body.split(',') {
            xs.push(parse_value(part.trim())?);
        }
        return Ok(TomlValue::Array(xs));
    }
    // Number: int first, then float.
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("unparseable value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typed_values() {
        let doc = TomlDoc::parse(
            r#"
# top comment
name = "fig3"   # trailing comment
count = 42
ratio = 0.25
on = true
seeds = [1, 2, 3]

[pso]
inertia = 0.01
particles = [5, 10]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("fig3"));
        assert_eq!(doc.get("", "count").unwrap().as_i64(), Some(42));
        assert_eq!(doc.get("", "ratio").unwrap().as_f64(), Some(0.25));
        assert_eq!(doc.get("", "on").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("pso", "inertia").unwrap().as_f64(), Some(0.01));
        let parts = doc.get("pso", "particles").unwrap().as_array().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn int_coerces_to_f64_not_reverse() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_f64(), Some(3.0));
        let doc = TomlDoc::parse("x = 3.5").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_i64(), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("good = 1\nbad line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_unterminated() {
        assert!(TomlDoc::parse("[table").is_err());
        assert!(TomlDoc::parse("x = \"oops").is_err());
        assert!(TomlDoc::parse("x = [1, 2").is_err());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = TomlDoc::parse("x = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_str(), Some("a#b"));
    }
}
