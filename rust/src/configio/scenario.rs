//! Typed experiment scenarios — the single source of truth shared by the
//! examples, benches and the CLI launcher. Loadable from TOML-lite files
//! or constructed from the paper's presets.

use super::toml_lite::TomlDoc;
use crate::pso::PsoConfig;

/// Simulation scenario (paper §IV.A/B — Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub struct SimScenario {
    /// Hierarchy depth D (levels of aggregators).
    pub depth: usize,
    /// Hierarchy width W (children per aggregator).
    pub width: usize,
    /// Trainers attached to each leaf-level aggregator (paper uses 2).
    pub trainers_per_leaf: usize,
    /// PSO hyper-parameters (swarm size, coefficients, iterations).
    pub pso: PsoConfig,
    /// Client attribute ranges (paper: pspeed ∈ (5,15), memcap ∈ (10,50),
    /// mdatasize = 5).
    pub pspeed_range: (f64, f64),
    pub memcap_range: (f64, f64),
    pub mdatasize: f64,
    /// Root seed for client attributes + optimizer randomness.
    pub seed: u64,
    /// Placement strategy (a `placement::registry` name; the CLI
    /// `--strategy` flag overrides it).
    pub strategy: String,
    /// Delay oracle (a `placement::registry` environment name:
    /// `analytic` or `event-driven`; the CLI `--env` flag overrides it).
    pub env: String,
    /// Discrete-event extensions (network model + dynamic behaviors)
    /// consumed by `des::EventDrivenEnv`. All-off by default, in which
    /// case the event-driven oracle reproduces [`AnalyticTpd`] scores.
    ///
    /// [`AnalyticTpd`]: crate::placement::AnalyticTpd
    pub des: DesSpec,
}

impl Default for SimScenario {
    fn default() -> Self {
        SimScenario {
            depth: 3,
            width: 4,
            trainers_per_leaf: 2,
            pso: PsoConfig::paper(),
            pspeed_range: (5.0, 15.0),
            memcap_range: (10.0, 50.0),
            mdatasize: 5.0,
            seed: 42,
            strategy: "pso".to_string(),
            env: "analytic".to_string(),
            des: DesSpec::default(),
        }
    }
}

impl SimScenario {
    /// The paper's Fig. 3 panel grid: (depth, width, particles) for
    /// panels (a)–(f). Width 4 with P=5 on the top row, P=10 on the
    /// bottom row, growing depth left→right.
    pub fn fig3_panels() -> Vec<(char, SimScenario)> {
        let mut panels = Vec::new();
        for (row, particles) in [(0usize, 5usize), (1, 10)] {
            for (col, depth) in [3usize, 4, 5].iter().enumerate() {
                let label = (b'a' + (row * 3 + col) as u8) as char;
                let mut sc = SimScenario {
                    depth: *depth,
                    ..SimScenario::default()
                };
                sc.pso.particles = particles;
                panels.push((label, sc));
            }
        }
        panels
    }

    /// Number of aggregator slots (paper Eq. 5): Σ_{i=0}^{D-1} W^i.
    pub fn dimensions(&self) -> usize {
        let mut total = 0usize;
        let mut level = 1usize;
        for _ in 0..self.depth {
            total += level;
            level *= self.width;
        }
        total
    }

    /// Number of leaf-level aggregators: W^(D-1).
    pub fn leaf_aggregators(&self) -> usize {
        self.width.pow(self.depth as u32 - 1)
    }

    /// Total clients = aggregator slots + leaf trainers.
    pub fn client_count(&self) -> usize {
        self.dimensions() + self.leaf_aggregators() * self.trainers_per_leaf
    }

    /// Load from a TOML-lite file with `[sim]` and `[pso]` tables.
    pub fn from_toml(doc: &TomlDoc) -> Result<SimScenario, String> {
        let mut sc = SimScenario::default();
        let get_usize = |t: &str, k: &str, d: usize| -> Result<usize, String> {
            match doc.get(t, k) {
                None => Ok(d),
                Some(v) => v.as_usize().ok_or_else(|| format!("{t}.{k}: expected integer")),
            }
        };
        let get_f64 = |t: &str, k: &str, d: f64| -> Result<f64, String> {
            match doc.get(t, k) {
                None => Ok(d),
                Some(v) => v.as_f64().ok_or_else(|| format!("{t}.{k}: expected number")),
            }
        };
        sc.depth = get_usize("sim", "depth", sc.depth)?;
        sc.width = get_usize("sim", "width", sc.width)?;
        if let Some(v) = doc.get("sim", "strategy") {
            sc.strategy = v
                .as_str()
                .ok_or_else(|| "sim.strategy: expected string".to_string())?
                .to_string();
        }
        sc.trainers_per_leaf = get_usize("sim", "trainers_per_leaf", sc.trainers_per_leaf)?;
        sc.seed = get_usize("sim", "seed", sc.seed as usize)? as u64;
        sc.mdatasize = get_f64("sim", "mdatasize", sc.mdatasize)?;
        sc.pspeed_range = (
            get_f64("sim", "pspeed_min", sc.pspeed_range.0)?,
            get_f64("sim", "pspeed_max", sc.pspeed_range.1)?,
        );
        sc.memcap_range = (
            get_f64("sim", "memcap_min", sc.memcap_range.0)?,
            get_f64("sim", "memcap_max", sc.memcap_range.1)?,
        );
        sc.pso.particles = get_usize("pso", "particles", sc.pso.particles)?;
        sc.pso.iterations = get_usize("pso", "iterations", sc.pso.iterations)?;
        sc.pso.inertia = get_f64("pso", "inertia", sc.pso.inertia)?;
        sc.pso.cognitive = get_f64("pso", "cognitive", sc.pso.cognitive)?;
        sc.pso.social = get_f64("pso", "social", sc.pso.social)?;
        sc.pso.velocity_factor = get_f64("pso", "velocity_factor", sc.pso.velocity_factor)?;
        if let Some(v) = doc.get("sim", "env") {
            sc.env = v
                .as_str()
                .ok_or_else(|| "sim.env: expected string".to_string())?
                .to_string();
        }
        sc.des.train_unit = get_f64("des", "train_unit", sc.des.train_unit)?;
        if let Some(v) = doc.get("des", "pipelined") {
            sc.des.pipelined = v
                .as_bool()
                .ok_or_else(|| "des.pipelined: expected boolean".to_string())?;
        }
        let n = &mut sc.des.net;
        n.latency_range_s = (
            get_f64("net", "latency_min", n.latency_range_s.0)?,
            get_f64("net", "latency_max", n.latency_range_s.1)?,
        );
        n.bandwidth_range = (
            get_f64("net", "bandwidth_min", n.bandwidth_range.0)?,
            get_f64("net", "bandwidth_max", n.bandwidth_range.1)?,
        );
        n.agg_ingress = get_f64("net", "agg_ingress", n.agg_ingress)?;
        n.jitter_sigma = get_f64("net", "jitter_sigma", n.jitter_sigma)?;
        n.up_mult_range = (
            get_f64("net", "up_min", n.up_mult_range.0)?,
            get_f64("net", "up_max", n.up_mult_range.1)?,
        );
        n.down_mult_range = (
            get_f64("net", "down_min", n.down_mult_range.0)?,
            get_f64("net", "down_max", n.down_mult_range.1)?,
        );
        let d = &mut sc.des.dynamics;
        d.dropout_prob = get_f64("dynamics", "dropout", d.dropout_prob)?;
        d.churn_leave_prob = get_f64("dynamics", "leave", d.churn_leave_prob)?;
        d.churn_join_prob = get_f64("dynamics", "join", d.churn_join_prob)?;
        d.straggler_prob = get_f64("dynamics", "straggler_prob", d.straggler_prob)?;
        d.straggler_frac = get_f64("dynamics", "straggler_frac", d.straggler_frac)?;
        d.straggler_slowdown = get_f64("dynamics", "straggler_slowdown", d.straggler_slowdown)?;
        d.drift_sigma = get_f64("dynamics", "drift", d.drift_sigma)?;
        d.corr_fail_prob = get_f64("dynamics", "corr_fail_prob", d.corr_fail_prob)?;
        d.corr_fail_frac = get_f64("dynamics", "corr_fail_frac", d.corr_fail_frac)?;
        d.partition_prob = get_f64("dynamics", "partition_prob", d.partition_prob)?;
        d.partition_frac = get_f64("dynamics", "partition_frac", d.partition_frac)?;
        d.partition_rounds = get_usize("dynamics", "partition_rounds", d.partition_rounds)?;
        if sc.depth == 0 || sc.width == 0 {
            return Err("sim.depth and sim.width must be >= 1".into());
        }
        sc.des.validate()?;
        Ok(sc)
    }
}

/// Per-link network parameters for the discrete-event simulator
/// (`des::NetworkModel` samples each client's uplink from these ranges).
/// Bandwidths are model-data units per virtual second (the same units as
/// `ClientAttrs::mdatasize`); `0.0` means "unlimited" for bandwidth-like
/// fields. All-zero defaults make the network free — the conformance
/// configuration where event-driven scores equal the analytic TPD.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetSpec {
    /// Per-client uplink propagation latency range (virtual seconds).
    pub latency_range_s: (f64, f64),
    /// Per-client uplink bandwidth range (data units / virtual second;
    /// 0.0 = unlimited).
    pub bandwidth_range: (f64, f64),
    /// Shared ingress capacity at each aggregator — concurrent uploads
    /// into the same aggregator serialize through it (0.0 = unlimited,
    /// i.e. no contention).
    pub agg_ingress: f64,
    /// Lognormal jitter sigma applied per transfer to the link latency
    /// (0.0 = deterministic links).
    pub jitter_sigma: f64,
    /// Bandwidth asymmetry: per-client *upload* multiplier range applied
    /// to the sampled base bandwidth (TOML `up_min`/`up_max`). `(0, 0)`
    /// disables the mechanism (multiplier 1). Enabled ranges must be
    /// strictly positive.
    pub up_mult_range: (f64, f64),
    /// Bandwidth asymmetry: per-client *download* multiplier range
    /// (TOML `down_min`/`down_max`). A client's download capacity caps
    /// the ingress service rate whenever it serves as an aggregator,
    /// so asymmetric links make placement quality download-sensitive.
    /// `(0, 0)` disables (unlimited downlink; only `agg_ingress` caps).
    pub down_mult_range: (f64, f64),
}

impl NetSpec {
    /// Whether the upload-multiplier mechanism is switched on.
    pub fn up_asymmetry_enabled(&self) -> bool {
        self.up_mult_range != (0.0, 0.0)
    }

    /// Whether the download-multiplier mechanism is switched on.
    pub fn down_asymmetry_enabled(&self) -> bool {
        self.down_mult_range != (0.0, 0.0)
    }
}

/// Dynamic-behavior parameters for the discrete-event scenario catalog.
/// All probabilities are per round; all-zero defaults mean a static
/// population (the conformance configuration).
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicsSpec {
    /// Per-trainer probability of silently dropping out of one round
    /// (its update never arrives; the aggregator merges the rest).
    pub dropout_prob: f64,
    /// Churn: per-round probability that a present trainer leaves the
    /// session (stays away until it rejoins).
    pub churn_leave_prob: f64,
    /// Churn: per-round probability that a departed trainer rejoins.
    pub churn_join_prob: f64,
    /// Probability that a round suffers a straggler burst.
    pub straggler_prob: f64,
    /// Fraction of clients slowed during a straggler burst.
    pub straggler_frac: f64,
    /// Compute slowdown multiplier applied to burst victims (>= 1).
    pub straggler_slowdown: f64,
    /// Per-round lognormal drift sigma on each client's effective speed
    /// (a bounded random walk; 0.0 = stationary speeds).
    pub drift_sigma: f64,
    /// Correlated failures: per-round probability that a *region* of
    /// clients (a contiguous id block — think one rack or one edge
    /// site) fails together for that round (TOML `corr_fail_prob`).
    pub corr_fail_prob: f64,
    /// Fraction of the population inside the failing region (TOML
    /// `corr_fail_frac`). Must be in (0, 1] when the mechanism is on.
    pub corr_fail_frac: f64,
    /// Network partition: per-round probability that a partition event
    /// *starts* (TOML `partition_prob`). While one is active no new one
    /// starts.
    pub partition_prob: f64,
    /// Fraction of the population cut off by a partition (TOML
    /// `partition_frac`). Must be in (0, 1] when the mechanism is on.
    pub partition_frac: f64,
    /// Rounds a partition lasts once started (TOML `partition_rounds`).
    /// Must be >= 1 when the mechanism is on.
    pub partition_rounds: usize,
}

impl Default for DynamicsSpec {
    fn default() -> Self {
        DynamicsSpec {
            dropout_prob: 0.0,
            churn_leave_prob: 0.0,
            churn_join_prob: 0.0,
            straggler_prob: 0.0,
            straggler_frac: 0.0,
            straggler_slowdown: 1.0,
            drift_sigma: 0.0,
            corr_fail_prob: 0.0,
            corr_fail_frac: 0.0,
            partition_prob: 0.0,
            partition_frac: 0.0,
            partition_rounds: 0,
        }
    }
}

impl DynamicsSpec {
    /// True when every dynamic behavior is switched off.
    pub fn is_static(&self) -> bool {
        self.dropout_prob == 0.0
            && self.churn_leave_prob == 0.0
            && self.churn_join_prob == 0.0
            && self.straggler_prob == 0.0
            && self.drift_sigma == 0.0
            && self.corr_fail_prob == 0.0
            && self.partition_prob == 0.0
    }
}

/// Discrete-event extensions of a [`SimScenario`] (TOML tables `[des]`,
/// `[net]` and `[dynamics]`). The defaults are the *conformance*
/// configuration: zero-cost links, no jitter, no churn/dropout, no
/// training cost and level-barrier synchronization — under which
/// `des::EventDrivenEnv` reproduces the analytic Eq. 6–7 TPD exactly.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DesSpec {
    /// Work units of one local training phase (delay = train_unit /
    /// effective pspeed; 0.0 = training not modeled, matching the
    /// analytic TPD which only counts aggregation).
    pub train_unit: f64,
    /// `false` = level-barrier synchronization (the paper's Eq. 7
    /// semantics: a level's merges start only when the whole level below
    /// delivered); `true` = fully event-driven overlap (each aggregator
    /// merges as soon as *its own* inputs arrive — never slower).
    pub pipelined: bool,
    pub net: NetSpec,
    pub dynamics: DynamicsSpec,
}

impl DesSpec {
    /// Reject out-of-range parameters with an actionable message.
    pub fn validate(&self) -> Result<(), String> {
        let prob = |name: &str, p: f64| -> Result<(), String> {
            if (0.0..=1.0).contains(&p) {
                Ok(())
            } else {
                Err(format!("dynamics.{name}: probability {p} outside [0, 1]"))
            }
        };
        prob("dropout", self.dynamics.dropout_prob)?;
        prob("leave", self.dynamics.churn_leave_prob)?;
        prob("join", self.dynamics.churn_join_prob)?;
        prob("straggler_prob", self.dynamics.straggler_prob)?;
        prob("straggler_frac", self.dynamics.straggler_frac)?;
        prob("corr_fail_prob", self.dynamics.corr_fail_prob)?;
        prob("corr_fail_frac", self.dynamics.corr_fail_frac)?;
        prob("partition_prob", self.dynamics.partition_prob)?;
        prob("partition_frac", self.dynamics.partition_frac)?;
        if self.dynamics.straggler_slowdown < 1.0 {
            return Err(format!(
                "dynamics.straggler_slowdown: {} must be >= 1",
                self.dynamics.straggler_slowdown
            ));
        }
        if self.dynamics.corr_fail_prob > 0.0 && self.dynamics.corr_fail_frac == 0.0 {
            return Err("dynamics.corr_fail_frac: must be > 0 when corr_fail_prob is".into());
        }
        if self.dynamics.partition_prob > 0.0 {
            if self.dynamics.partition_frac == 0.0 {
                return Err("dynamics.partition_frac: must be > 0 when partition_prob is".into());
            }
            if self.dynamics.partition_rounds == 0 {
                return Err(
                    "dynamics.partition_rounds: must be >= 1 when partition_prob is > 0".into()
                );
            }
        }
        for (name, (lo, hi)) in [
            ("net.latency", self.net.latency_range_s),
            ("net.bandwidth", self.net.bandwidth_range),
        ] {
            if lo < 0.0 || hi < lo {
                return Err(format!("{name}: bad range ({lo}, {hi})"));
            }
        }
        for (name, range, enabled) in [
            ("net.up_min/up_max", self.net.up_mult_range, self.net.up_asymmetry_enabled()),
            ("net.down_min/down_max", self.net.down_mult_range, self.net.down_asymmetry_enabled()),
        ] {
            if enabled && (range.0 <= 0.0 || range.1 < range.0) {
                return Err(format!(
                    "{name}: multiplier range ({}, {}) must be positive with max >= min \
                     (or (0, 0) to disable)",
                    range.0, range.1
                ));
            }
        }
        if self.net.agg_ingress < 0.0 || self.net.jitter_sigma < 0.0 || self.train_unit < 0.0 {
            return Err("net/des parameters must be non-negative".into());
        }
        Ok(())
    }
}

/// One emulated client in the deployment scenario (docker substitute —
/// DESIGN.md §4).
#[derive(Debug, Clone, PartialEq)]
pub struct ClientSpec {
    /// Human label ("big", "mid0", "small3", ...).
    pub name: String,
    /// Compute slowdown multiplier (1.0 = full speed). Applied to both
    /// training and aggregation wall time.
    pub speed_factor: f64,
    /// Extra aggregation slowdown modeling memory pressure / swap
    /// (paper's 64 MB containers swap while merging 30 MB JSON models).
    pub memory_pressure: f64,
}

/// Deployment scenario (paper §IV.C — Fig. 4).
#[derive(Debug, Clone, PartialEq)]
pub struct DeployScenario {
    pub clients: Vec<ClientSpec>,
    /// Aggregation hierarchy depth/width over the clients.
    pub depth: usize,
    pub width: usize,
    /// FL rounds to run (paper: 50).
    pub rounds: usize,
    /// Local SGD steps per trainer per round.
    pub local_steps: usize,
    /// Learning rate for local steps.
    pub lr: f32,
    pub pso: PsoConfig,
    pub seed: u64,
    /// Child-update timeout for agents (seconds): how long a trainer
    /// waits for the global model and an aggregator waits for each
    /// child's update before proceeding partial. TOML `[deploy]
    /// child_timeout_secs`; must be > 0 (historically a buried 120 s
    /// constant in `fl::Deployment::launch`).
    pub child_timeout_secs: f64,
}

impl DeployScenario {
    /// The paper's 10-container docker scenario: one big client
    /// (3 cores / 2 GB), two medium (1 core / 1 GB), seven small
    /// (1 core / 64 MB + swap). Speed factors calibrate the same
    /// ordering: big ≈ 3× faster than medium; small pays a heavy
    /// aggregation penalty (swap thrash on 30 MB models).
    pub fn paper_docker() -> DeployScenario {
        let mut clients = vec![ClientSpec {
            name: "big".into(),
            speed_factor: 1.0,
            memory_pressure: 1.0,
        }];
        for i in 0..2 {
            clients.push(ClientSpec {
                name: format!("mid{i}"),
                speed_factor: 3.0,
                memory_pressure: 1.5,
            });
        }
        for i in 0..7 {
            clients.push(ClientSpec {
                name: format!("small{i}"),
                speed_factor: 3.5,
                memory_pressure: 6.0,
            });
        }
        let mut pso = PsoConfig::paper();
        // Live deployments pay one real FL round per fitness evaluation;
        // a 5-particle swarm (the paper's small-swarm setting) pins
        // within ~2 sweeps ≈ 10 rounds — matching Fig. 4's observed
        // convergence "after the 10th round".
        pso.particles = 5;
        DeployScenario {
            clients,
            depth: 2,
            width: 2,
            rounds: 50,
            local_steps: 1,
            lr: 0.05,
            pso,
            seed: 7,
            // Generous: the slowest emulated aggregation must fit.
            child_timeout_secs: 120.0,
        }
    }

    /// Aggregator slots in the deployment hierarchy (Eq. 5).
    pub fn dimensions(&self) -> usize {
        let mut total = 0;
        let mut level = 1;
        for _ in 0..self.depth {
            total += level;
            level *= self.width;
        }
        total
    }

    /// Load overrides from a TOML-lite `[deploy]` table on top of the
    /// paper preset. Recognized keys: `clients` (generates that many
    /// uniform full-speed clients in place of the paper's mix), `depth`,
    /// `width`, `rounds`, `local_steps`, `lr`, `seed`,
    /// `child_timeout_secs`.
    pub fn from_toml(doc: &TomlDoc) -> Result<DeployScenario, String> {
        let mut sc = DeployScenario::paper_docker();
        let get_usize = |k: &str, d: usize| -> Result<usize, String> {
            match doc.get("deploy", k) {
                None => Ok(d),
                Some(v) => v.as_usize().ok_or_else(|| format!("deploy.{k}: expected integer")),
            }
        };
        let get_f64 = |k: &str, d: f64| -> Result<f64, String> {
            match doc.get("deploy", k) {
                None => Ok(d),
                Some(v) => v.as_f64().ok_or_else(|| format!("deploy.{k}: expected number")),
            }
        };
        if let Some(v) = doc.get("deploy", "clients") {
            let n = v.as_usize().ok_or("deploy.clients: expected integer")?;
            sc.clients = (0..n)
                .map(|i| ClientSpec {
                    name: format!("c{i}"),
                    speed_factor: 1.0,
                    memory_pressure: 1.0,
                })
                .collect();
        }
        sc.depth = get_usize("depth", sc.depth)?;
        sc.width = get_usize("width", sc.width)?;
        sc.rounds = get_usize("rounds", sc.rounds)?;
        sc.local_steps = get_usize("local_steps", sc.local_steps)?;
        sc.lr = get_f64("lr", sc.lr as f64)? as f32;
        sc.seed = get_usize("seed", sc.seed as usize)? as u64;
        sc.child_timeout_secs = get_f64("child_timeout_secs", sc.child_timeout_secs)?;
        sc.validate()?;
        Ok(sc)
    }

    /// Reject inconsistent deployment parameters.
    pub fn validate(&self) -> Result<(), String> {
        if self.child_timeout_secs <= 0.0 || !self.child_timeout_secs.is_finite() {
            return Err(format!(
                "deploy.child_timeout_secs: must be a finite number > 0, got {}",
                self.child_timeout_secs
            ));
        }
        if self.depth == 0 || self.width == 0 {
            return Err("deploy.depth and deploy.width must be >= 1".into());
        }
        if self.rounds == 0 {
            return Err("deploy.rounds must be >= 1".into());
        }
        if self.clients.len() < self.dimensions() {
            return Err(format!(
                "deploy: {} clients cannot host {} aggregator slots",
                self.clients.len(),
                self.dimensions()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_eq5() {
        // D=3, W=4: 1 + 4 + 16 = 21.
        let sc = SimScenario::default();
        assert_eq!(sc.dimensions(), 21);
        assert_eq!(sc.leaf_aggregators(), 16);
        assert_eq!(sc.client_count(), 21 + 32);
    }

    #[test]
    fn fig3_panels_match_paper_grid() {
        let panels = SimScenario::fig3_panels();
        assert_eq!(panels.len(), 6);
        assert_eq!(panels[0].0, 'a');
        assert_eq!(panels[0].1.pso.particles, 5);
        assert_eq!(panels[3].0, 'd');
        assert_eq!(panels[3].1.pso.particles, 10);
        // Client count grows left to right within a row.
        assert!(panels[1].1.client_count() > panels[0].1.client_count());
        assert!(panels[2].1.client_count() > panels[1].1.client_count());
    }

    #[test]
    fn toml_roundtrip() {
        let doc = TomlDoc::parse(
            r#"
[sim]
depth = 4
width = 5
seed = 9

[pso]
particles = 10
inertia = 0.4
"#,
        )
        .unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.depth, 4);
        assert_eq!(sc.width, 5);
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.pso.particles, 10);
        assert!((sc.pso.inertia - 0.4).abs() < 1e-12);
        // Unset keys keep paper defaults.
        assert!((sc.pso.social - 1.0).abs() < 1e-12);
        assert_eq!(sc.strategy, "pso");
    }

    #[test]
    fn toml_strategy_key_parses() {
        let doc = TomlDoc::parse("[sim]\nstrategy = \"ga\"\n").unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.strategy, "ga");
    }

    #[test]
    fn toml_des_tables_parse() {
        let doc = TomlDoc::parse(
            r#"
[sim]
depth = 3
width = 2
env = "event-driven"

[des]
train_unit = 2.5
pipelined = true

[net]
latency_min = 0.001
latency_max = 0.02
bandwidth_min = 5.0
bandwidth_max = 50.0
agg_ingress = 100.0
jitter_sigma = 0.5

[dynamics]
dropout = 0.1
leave = 0.05
join = 0.5
straggler_prob = 0.3
straggler_frac = 0.2
straggler_slowdown = 4.0
drift = 0.05
"#,
        )
        .unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.env, "event-driven");
        assert!(sc.des.pipelined);
        assert!((sc.des.train_unit - 2.5).abs() < 1e-12);
        assert_eq!(sc.des.net.latency_range_s, (0.001, 0.02));
        assert_eq!(sc.des.net.bandwidth_range, (5.0, 50.0));
        assert_eq!(sc.des.net.agg_ingress, 100.0);
        assert!(!sc.des.dynamics.is_static());
        assert_eq!(sc.des.dynamics.dropout_prob, 0.1);
        assert_eq!(sc.des.dynamics.straggler_slowdown, 4.0);
    }

    #[test]
    fn toml_defaults_are_conformance_config() {
        let doc = TomlDoc::parse("[sim]\ndepth = 2\n").unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.env, "analytic");
        assert_eq!(sc.des, DesSpec::default());
        assert!(sc.des.dynamics.is_static());
        assert!(!sc.des.pipelined);
        assert_eq!(sc.des.train_unit, 0.0);
    }

    #[test]
    fn toml_new_mechanism_keys_roundtrip() {
        let doc = TomlDoc::parse(
            r#"
[sim]
depth = 2
width = 2
env = "event-driven"

[net]
bandwidth_min = 5.0
bandwidth_max = 50.0
up_min = 0.5
up_max = 1.0
down_min = 0.25
down_max = 1.0

[dynamics]
corr_fail_prob = 0.2
corr_fail_frac = 0.3
partition_prob = 0.1
partition_frac = 0.25
partition_rounds = 3
"#,
        )
        .unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.des.net.up_mult_range, (0.5, 1.0));
        assert_eq!(sc.des.net.down_mult_range, (0.25, 1.0));
        assert!(sc.des.net.up_asymmetry_enabled() && sc.des.net.down_asymmetry_enabled());
        assert_eq!(sc.des.dynamics.corr_fail_prob, 0.2);
        assert_eq!(sc.des.dynamics.corr_fail_frac, 0.3);
        assert_eq!(sc.des.dynamics.partition_prob, 0.1);
        assert_eq!(sc.des.dynamics.partition_frac, 0.25);
        assert_eq!(sc.des.dynamics.partition_rounds, 3);
        assert!(!sc.des.dynamics.is_static());
    }

    #[test]
    fn toml_defaults_leave_new_mechanisms_off() {
        let doc = TomlDoc::parse("[sim]\ndepth = 2\n").unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert!(!sc.des.net.up_asymmetry_enabled());
        assert!(!sc.des.net.down_asymmetry_enabled());
        assert_eq!(sc.des.dynamics.corr_fail_prob, 0.0);
        assert_eq!(sc.des.dynamics.partition_prob, 0.0);
        assert!(sc.des.dynamics.is_static());
    }

    #[test]
    fn toml_rejects_bad_new_mechanism_parameters() {
        // Partition with no duration.
        let doc =
            TomlDoc::parse("[dynamics]\npartition_prob = 0.2\npartition_frac = 0.3\n").unwrap();
        let err = SimScenario::from_toml(&doc).unwrap_err();
        assert!(err.contains("partition_rounds"), "{err}");
        // Correlated failure with no region size.
        let doc = TomlDoc::parse("[dynamics]\ncorr_fail_prob = 0.2\n").unwrap();
        let err = SimScenario::from_toml(&doc).unwrap_err();
        assert!(err.contains("corr_fail_frac"), "{err}");
        // Out-of-range probability.
        let doc = TomlDoc::parse("[dynamics]\npartition_prob = 1.5\n").unwrap();
        assert!(SimScenario::from_toml(&doc).is_err());
        // Zero-crossing asymmetry multiplier range.
        let doc = TomlDoc::parse("[net]\nup_min = 0.0\nup_max = 2.0\n").unwrap();
        let err = SimScenario::from_toml(&doc).unwrap_err();
        assert!(err.contains("up_min"), "{err}");
        // Inverted range.
        let doc = TomlDoc::parse("[net]\ndown_min = 1.0\ndown_max = 0.5\n").unwrap();
        assert!(SimScenario::from_toml(&doc).is_err());
    }

    #[test]
    fn toml_rejects_bad_probabilities() {
        let doc = TomlDoc::parse("[dynamics]\ndropout = 1.5\n").unwrap();
        let err = SimScenario::from_toml(&doc).unwrap_err();
        assert!(err.contains("dropout"), "{err}");
        let doc = TomlDoc::parse("[dynamics]\nstraggler_slowdown = 0.5\n").unwrap();
        assert!(SimScenario::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[net]\nlatency_min = 0.5\nlatency_max = 0.1\n").unwrap();
        assert!(SimScenario::from_toml(&doc).is_err());
    }

    #[test]
    fn toml_rejects_zero_depth() {
        let doc = TomlDoc::parse("[sim]\ndepth = 0\n").unwrap();
        assert!(SimScenario::from_toml(&doc).is_err());
    }

    #[test]
    fn paper_docker_composition() {
        let d = DeployScenario::paper_docker();
        assert_eq!(d.clients.len(), 10);
        assert_eq!(d.rounds, 50);
        assert_eq!(d.dimensions(), 3); // root + 2 leaf aggregators
        // Exactly one full-speed client.
        assert_eq!(d.clients.iter().filter(|c| c.speed_factor == 1.0).count(), 1);
        // The once-hardcoded child timeout surfaces as a validated field.
        assert_eq!(d.child_timeout_secs, 120.0);
        d.validate().unwrap();
    }

    #[test]
    fn deploy_toml_overrides_and_validates() {
        let doc = TomlDoc::parse(
            r#"
[deploy]
clients = 6
depth = 2
width = 2
rounds = 3
seed = 99
child_timeout_secs = 2.5
"#,
        )
        .unwrap();
        let sc = DeployScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.clients.len(), 6);
        assert_eq!(sc.rounds, 3);
        assert_eq!(sc.seed, 99);
        assert!((sc.child_timeout_secs - 2.5).abs() < 1e-12);
        // No [deploy] table at all → the paper preset.
        let empty = TomlDoc::parse("").unwrap();
        assert_eq!(DeployScenario::from_toml(&empty).unwrap(), DeployScenario::paper_docker());
    }

    #[test]
    fn deploy_toml_rejects_bad_child_timeout() {
        for bad in ["0", "-5.0"] {
            let doc =
                TomlDoc::parse(&format!("[deploy]\nchild_timeout_secs = {bad}\n")).unwrap();
            let err = DeployScenario::from_toml(&doc).unwrap_err();
            assert!(err.contains("child_timeout_secs"), "{err}");
        }
        // Too few clients for the hierarchy.
        let doc = TomlDoc::parse("[deploy]\nclients = 2\n").unwrap();
        let err = DeployScenario::from_toml(&doc).unwrap_err();
        assert!(err.contains("aggregator slots"), "{err}");
    }
}
