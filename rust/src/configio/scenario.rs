//! Typed experiment scenarios — the single source of truth shared by the
//! examples, benches and the CLI launcher. Loadable from TOML-lite files
//! or constructed from the paper's presets.

use super::toml_lite::TomlDoc;
use crate::pso::PsoConfig;

/// Simulation scenario (paper §IV.A/B — Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub struct SimScenario {
    /// Hierarchy depth D (levels of aggregators).
    pub depth: usize,
    /// Hierarchy width W (children per aggregator).
    pub width: usize,
    /// Trainers attached to each leaf-level aggregator (paper uses 2).
    pub trainers_per_leaf: usize,
    /// PSO hyper-parameters (swarm size, coefficients, iterations).
    pub pso: PsoConfig,
    /// Client attribute ranges (paper: pspeed ∈ (5,15), memcap ∈ (10,50),
    /// mdatasize = 5).
    pub pspeed_range: (f64, f64),
    pub memcap_range: (f64, f64),
    pub mdatasize: f64,
    /// Root seed for client attributes + optimizer randomness.
    pub seed: u64,
    /// Placement strategy (a `placement::registry` name; the CLI
    /// `--strategy` flag overrides it).
    pub strategy: String,
}

impl Default for SimScenario {
    fn default() -> Self {
        SimScenario {
            depth: 3,
            width: 4,
            trainers_per_leaf: 2,
            pso: PsoConfig::paper(),
            pspeed_range: (5.0, 15.0),
            memcap_range: (10.0, 50.0),
            mdatasize: 5.0,
            seed: 42,
            strategy: "pso".to_string(),
        }
    }
}

impl SimScenario {
    /// The paper's Fig. 3 panel grid: (depth, width, particles) for
    /// panels (a)–(f). Width 4 with P=5 on the top row, P=10 on the
    /// bottom row, growing depth left→right.
    pub fn fig3_panels() -> Vec<(char, SimScenario)> {
        let mut panels = Vec::new();
        for (row, particles) in [(0usize, 5usize), (1, 10)] {
            for (col, depth) in [3usize, 4, 5].iter().enumerate() {
                let label = (b'a' + (row * 3 + col) as u8) as char;
                let mut sc = SimScenario {
                    depth: *depth,
                    ..SimScenario::default()
                };
                sc.pso.particles = particles;
                panels.push((label, sc));
            }
        }
        panels
    }

    /// Number of aggregator slots (paper Eq. 5): Σ_{i=0}^{D-1} W^i.
    pub fn dimensions(&self) -> usize {
        let mut total = 0usize;
        let mut level = 1usize;
        for _ in 0..self.depth {
            total += level;
            level *= self.width;
        }
        total
    }

    /// Number of leaf-level aggregators: W^(D-1).
    pub fn leaf_aggregators(&self) -> usize {
        self.width.pow(self.depth as u32 - 1)
    }

    /// Total clients = aggregator slots + leaf trainers.
    pub fn client_count(&self) -> usize {
        self.dimensions() + self.leaf_aggregators() * self.trainers_per_leaf
    }

    /// Load from a TOML-lite file with `[sim]` and `[pso]` tables.
    pub fn from_toml(doc: &TomlDoc) -> Result<SimScenario, String> {
        let mut sc = SimScenario::default();
        let get_usize = |t: &str, k: &str, d: usize| -> Result<usize, String> {
            match doc.get(t, k) {
                None => Ok(d),
                Some(v) => v.as_usize().ok_or_else(|| format!("{t}.{k}: expected integer")),
            }
        };
        let get_f64 = |t: &str, k: &str, d: f64| -> Result<f64, String> {
            match doc.get(t, k) {
                None => Ok(d),
                Some(v) => v.as_f64().ok_or_else(|| format!("{t}.{k}: expected number")),
            }
        };
        sc.depth = get_usize("sim", "depth", sc.depth)?;
        sc.width = get_usize("sim", "width", sc.width)?;
        if let Some(v) = doc.get("sim", "strategy") {
            sc.strategy = v
                .as_str()
                .ok_or_else(|| "sim.strategy: expected string".to_string())?
                .to_string();
        }
        sc.trainers_per_leaf = get_usize("sim", "trainers_per_leaf", sc.trainers_per_leaf)?;
        sc.seed = get_usize("sim", "seed", sc.seed as usize)? as u64;
        sc.mdatasize = get_f64("sim", "mdatasize", sc.mdatasize)?;
        sc.pspeed_range = (
            get_f64("sim", "pspeed_min", sc.pspeed_range.0)?,
            get_f64("sim", "pspeed_max", sc.pspeed_range.1)?,
        );
        sc.memcap_range = (
            get_f64("sim", "memcap_min", sc.memcap_range.0)?,
            get_f64("sim", "memcap_max", sc.memcap_range.1)?,
        );
        sc.pso.particles = get_usize("pso", "particles", sc.pso.particles)?;
        sc.pso.iterations = get_usize("pso", "iterations", sc.pso.iterations)?;
        sc.pso.inertia = get_f64("pso", "inertia", sc.pso.inertia)?;
        sc.pso.cognitive = get_f64("pso", "cognitive", sc.pso.cognitive)?;
        sc.pso.social = get_f64("pso", "social", sc.pso.social)?;
        sc.pso.velocity_factor = get_f64("pso", "velocity_factor", sc.pso.velocity_factor)?;
        if sc.depth == 0 || sc.width == 0 {
            return Err("sim.depth and sim.width must be >= 1".into());
        }
        Ok(sc)
    }
}

/// One emulated client in the deployment scenario (docker substitute —
/// DESIGN.md §4).
#[derive(Debug, Clone, PartialEq)]
pub struct ClientSpec {
    /// Human label ("big", "mid0", "small3", ...).
    pub name: String,
    /// Compute slowdown multiplier (1.0 = full speed). Applied to both
    /// training and aggregation wall time.
    pub speed_factor: f64,
    /// Extra aggregation slowdown modeling memory pressure / swap
    /// (paper's 64 MB containers swap while merging 30 MB JSON models).
    pub memory_pressure: f64,
}

/// Deployment scenario (paper §IV.C — Fig. 4).
#[derive(Debug, Clone, PartialEq)]
pub struct DeployScenario {
    pub clients: Vec<ClientSpec>,
    /// Aggregation hierarchy depth/width over the clients.
    pub depth: usize,
    pub width: usize,
    /// FL rounds to run (paper: 50).
    pub rounds: usize,
    /// Local SGD steps per trainer per round.
    pub local_steps: usize,
    /// Learning rate for local steps.
    pub lr: f32,
    pub pso: PsoConfig,
    pub seed: u64,
}

impl DeployScenario {
    /// The paper's 10-container docker scenario: one big client
    /// (3 cores / 2 GB), two medium (1 core / 1 GB), seven small
    /// (1 core / 64 MB + swap). Speed factors calibrate the same
    /// ordering: big ≈ 3× faster than medium; small pays a heavy
    /// aggregation penalty (swap thrash on 30 MB models).
    pub fn paper_docker() -> DeployScenario {
        let mut clients = vec![ClientSpec {
            name: "big".into(),
            speed_factor: 1.0,
            memory_pressure: 1.0,
        }];
        for i in 0..2 {
            clients.push(ClientSpec {
                name: format!("mid{i}"),
                speed_factor: 3.0,
                memory_pressure: 1.5,
            });
        }
        for i in 0..7 {
            clients.push(ClientSpec {
                name: format!("small{i}"),
                speed_factor: 3.5,
                memory_pressure: 6.0,
            });
        }
        let mut pso = PsoConfig::paper();
        // Live deployments pay one real FL round per fitness evaluation;
        // a 5-particle swarm (the paper's small-swarm setting) pins
        // within ~2 sweeps ≈ 10 rounds — matching Fig. 4's observed
        // convergence "after the 10th round".
        pso.particles = 5;
        DeployScenario {
            clients,
            depth: 2,
            width: 2,
            rounds: 50,
            local_steps: 1,
            lr: 0.05,
            pso,
            seed: 7,
        }
    }

    /// Aggregator slots in the deployment hierarchy (Eq. 5).
    pub fn dimensions(&self) -> usize {
        let mut total = 0;
        let mut level = 1;
        for _ in 0..self.depth {
            total += level;
            level *= self.width;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_eq5() {
        // D=3, W=4: 1 + 4 + 16 = 21.
        let sc = SimScenario::default();
        assert_eq!(sc.dimensions(), 21);
        assert_eq!(sc.leaf_aggregators(), 16);
        assert_eq!(sc.client_count(), 21 + 32);
    }

    #[test]
    fn fig3_panels_match_paper_grid() {
        let panels = SimScenario::fig3_panels();
        assert_eq!(panels.len(), 6);
        assert_eq!(panels[0].0, 'a');
        assert_eq!(panels[0].1.pso.particles, 5);
        assert_eq!(panels[3].0, 'd');
        assert_eq!(panels[3].1.pso.particles, 10);
        // Client count grows left to right within a row.
        assert!(panels[1].1.client_count() > panels[0].1.client_count());
        assert!(panels[2].1.client_count() > panels[1].1.client_count());
    }

    #[test]
    fn toml_roundtrip() {
        let doc = TomlDoc::parse(
            r#"
[sim]
depth = 4
width = 5
seed = 9

[pso]
particles = 10
inertia = 0.4
"#,
        )
        .unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.depth, 4);
        assert_eq!(sc.width, 5);
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.pso.particles, 10);
        assert!((sc.pso.inertia - 0.4).abs() < 1e-12);
        // Unset keys keep paper defaults.
        assert!((sc.pso.social - 1.0).abs() < 1e-12);
        assert_eq!(sc.strategy, "pso");
    }

    #[test]
    fn toml_strategy_key_parses() {
        let doc = TomlDoc::parse("[sim]\nstrategy = \"ga\"\n").unwrap();
        let sc = SimScenario::from_toml(&doc).unwrap();
        assert_eq!(sc.strategy, "ga");
    }

    #[test]
    fn toml_rejects_zero_depth() {
        let doc = TomlDoc::parse("[sim]\ndepth = 0\n").unwrap();
        assert!(SimScenario::from_toml(&doc).is_err());
    }

    #[test]
    fn paper_docker_composition() {
        let d = DeployScenario::paper_docker();
        assert_eq!(d.clients.len(), 10);
        assert_eq!(d.rounds, 50);
        assert_eq!(d.dimensions(), 3); // root + 2 leaf aggregators
        // Exactly one full-speed client.
        assert_eq!(d.clients.iter().filter(|c| c.speed_factor == 1.0).count(), 1);
    }
}
