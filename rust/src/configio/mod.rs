//! Configuration system (substrate — no `clap`/`toml` offline).
//!
//! * [`toml_lite`] — the subset of TOML the scenario files use:
//!   `[table]` headers, `key = value` with strings / integers / floats /
//!   booleans / homogeneous arrays, comments.
//! * [`cli`] — subcommand + `--flag value` / `--flag=value` parsing for
//!   the `repro` launcher and the examples.
//! * [`scenario`] — typed experiment configs (simulation grids, the
//!   docker-analogue deployment) loadable from TOML or built from
//!   presets; single source of truth shared by examples and benches.

pub mod cli;
pub mod scenario;
pub mod toml_lite;

pub use cli::Args;
pub use scenario::{ClientSpec, DeployScenario, DesSpec, DynamicsSpec, NetSpec, SimScenario};
pub use toml_lite::TomlDoc;
