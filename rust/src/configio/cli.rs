//! Flag parsing for the `repro` launcher and examples (substrate — no
//! `clap` offline). Grammar: `prog [subcommand] [--key value|--key=value|
//! --switch]... [positional]...`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token, if any (launcher subcommand).
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` pairs; bare `--switch` maps to "true".
    flags: BTreeMap<String, String>,
    /// Remaining positionals after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) — first token is NOT argv[0].
    ///
    /// A repeated flag is a hard error: silently keeping the first (or
    /// last) occurrence turns `--evals 10 --evals 99` into whichever
    /// budget the caller did *not* mean, which is exactly the kind of
    /// quiet misconfiguration a reproduction harness cannot afford.
    pub fn parse_tokens<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if flag.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = flag.split_once('=') {
                    insert_unique(&mut args.flags, k, v.to_string())?;
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    insert_unique(&mut args.flags, flag, it.next().unwrap())?;
                } else {
                    insert_unique(&mut args.flags, flag, "true".to_string())?;
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (skips argv[0]).
    pub fn parse_env() -> Result<Args, String> {
        Args::parse_tokens(std::env::args().skip(1))
    }

    /// Raw flag value.
    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    /// usize flag with default; error message names the flag.
    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    /// Optional usize flag: `None` when absent (no default applies,
    /// e.g. `repro fleet --evals` overriding per-scenario budgets).
    pub fn opt_usize_flag(&self, key: &str) -> Result<Option<usize>, String> {
        self.flag(key)
            .map(|v| v.parse().map_err(|_| format!("--{key}: expected integer, got {v:?}")))
            .transpose()
    }

    /// f64 flag with default.
    pub fn f64_flag(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected number, got {v:?}")),
        }
    }

    /// u64 flag with default (seeds).
    pub fn u64_flag(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    /// Boolean switch: present (or `=true`) ⇒ true.
    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag (`--strategies random,uniform,pso`).
    /// Empty entries are dropped; `None` when the flag is absent.
    /// Repeating the flag itself is a parse-time error; lists are
    /// expressed in one comma-separated value.
    pub fn list_flag(&self, key: &str) -> Option<Vec<String>> {
        self.flag(key).map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
    }
}

/// Insert a flag, rejecting duplicates with an actionable message.
fn insert_unique(
    flags: &mut BTreeMap<String, String>,
    key: &str,
    value: String,
) -> Result<(), String> {
    if let Some(first) = flags.get(key) {
        return Err(format!(
            "--{key} given more than once ({first:?}, then {value:?}); \
             each flag may appear at most once"
        ));
    }
    flags.insert(key.to_string(), value);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_tokens(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("sim trailing --depth 4 --width=5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("sim"));
        assert_eq!(a.usize_flag("depth", 0).unwrap(), 4);
        assert_eq!(a.usize_flag("width", 0).unwrap(), 5);
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.positional, vec!["trailing"]);
    }

    #[test]
    fn switch_before_positional_consumes_it_as_value() {
        // Documented ambiguity: `--verbose trailing` binds "trailing" as
        // the value of --verbose. Callers place switches last or use `=`.
        let a = parse("sim --verbose trailing");
        assert_eq!(a.flag("verbose"), Some("trailing"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize_flag("rounds", 50).unwrap(), 50);
        assert_eq!(a.f64_flag("inertia", 0.01).unwrap(), 0.01);
        assert!(!a.bool_flag("verbose"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("run --rounds abc");
        assert!(a.usize_flag("rounds", 1).is_err());
    }

    #[test]
    fn switch_followed_by_flag() {
        let a = parse("--dry-run --seed 9");
        assert!(a.bool_flag("dry-run"));
        assert_eq!(a.u64_flag("seed", 0).unwrap(), 9);
    }

    #[test]
    fn sim_strategy_flag_parses() {
        // `repro sim --strategy ga` — the registry-driven launcher form.
        let a = parse("sim --strategy ga");
        assert_eq!(a.subcommand.as_deref(), Some("sim"));
        assert_eq!(a.flag("strategy"), Some("ga"));
        assert_eq!(a.str_flag("strategy", "pso"), "ga");
    }

    #[test]
    fn fleet_flags_parse() {
        let a = parse(
            "fleet --scenarios builtin --filter tiny --strategies pso,random \
             --threads 8 --evals 40 --replicates 5",
        );
        assert_eq!(a.subcommand.as_deref(), Some("fleet"));
        assert_eq!(a.str_flag("scenarios", "builtin"), "builtin");
        assert_eq!(a.flag("filter"), Some("tiny"));
        assert_eq!(a.usize_flag("threads", 0).unwrap(), 8);
        assert_eq!(a.usize_flag("replicates", 1).unwrap(), 5);
        assert_eq!(a.opt_usize_flag("evals").unwrap(), Some(40));
        assert_eq!(a.opt_usize_flag("absent").unwrap(), None);
        assert!(parse("fleet --evals x").opt_usize_flag("evals").is_err());
        assert!(parse("fleet --replicates x").usize_flag("replicates", 1).is_err());
    }

    #[test]
    fn duplicate_flags_are_a_hard_error() {
        // `--evals 10 --evals 99` used to silently keep the first value;
        // now every repetition form is rejected with both values named.
        let err = Args::parse_tokens(
            "fleet --evals 10 --evals 99".split_whitespace().map(String::from),
        )
        .unwrap_err();
        assert!(err.contains("--evals"), "{err}");
        assert!(err.contains("10") && err.contains("99"), "{err}");
        assert!(err.contains("more than once"), "{err}");
        // `=` and space forms collide too.
        let err = Args::parse_tokens(
            "sim --seed=1 --seed 2".split_whitespace().map(String::from),
        )
        .unwrap_err();
        assert!(err.contains("--seed"), "{err}");
        // Repeated bare switches as well.
        let err = Args::parse_tokens(
            "sim --verbose --verbose".split_whitespace().map(String::from),
        )
        .unwrap_err();
        assert!(err.contains("--verbose"), "{err}");
        // Distinct flags still parse.
        let a = parse("sim --seed 1 --evals 2");
        assert_eq!(a.u64_flag("seed", 0).unwrap(), 1);
    }

    #[test]
    fn strategies_list_flag_parses() {
        let a = parse("compare --strategies random,uniform,pso");
        assert_eq!(
            a.list_flag("strategies").unwrap(),
            vec!["random", "uniform", "pso"]
        );
        assert_eq!(a.list_flag("absent"), None);
        let b = parse("compare --strategies=ga,,sa");
        assert_eq!(b.list_flag("strategies").unwrap(), vec!["ga", "sa"]);
    }
}
