//! Simulated client attributes (paper §IV.A).

use crate::prng::{Pcg32, Rng};

/// Per-client attributes used by the simulation fitness model.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientAttrs {
    /// Unique client id (index into the population).
    pub client_id: usize,
    /// Memory capacity (paper: uniform in (10, 50)).
    pub memcap: f64,
    /// Model data size processed/forwarded by the client (paper: fixed 5).
    pub mdatasize: f64,
    /// Processing speed (paper: uniform in (5, 15)).
    pub pspeed: f64,
}

impl ClientAttrs {
    /// Sample a population of `n` clients with the paper's attribute
    /// distributions (or custom ranges from the scenario).
    pub fn sample_population(
        n: usize,
        pspeed_range: (f64, f64),
        memcap_range: (f64, f64),
        mdatasize: f64,
        rng: &mut Pcg32,
    ) -> Vec<ClientAttrs> {
        (0..n)
            .map(|client_id| ClientAttrs {
                client_id,
                memcap: rng.uniform(memcap_range.0, memcap_range.1),
                mdatasize,
                pspeed: rng.uniform(pspeed_range.0, pspeed_range.1),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_respects_ranges() {
        let mut rng = Pcg32::seed_from_u64(1);
        let pop = ClientAttrs::sample_population(500, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
        assert_eq!(pop.len(), 500);
        for (i, c) in pop.iter().enumerate() {
            assert_eq!(c.client_id, i);
            assert!((5.0..15.0).contains(&c.pspeed));
            assert!((10.0..50.0).contains(&c.memcap));
            assert_eq!(c.mdatasize, 5.0);
        }
    }

    #[test]
    fn population_deterministic_per_seed() {
        let mut a = Pcg32::seed_from_u64(9);
        let mut b = Pcg32::seed_from_u64(9);
        let pa = ClientAttrs::sample_population(50, (5.0, 15.0), (10.0, 50.0), 5.0, &mut a);
        let pb = ClientAttrs::sample_population(50, (5.0, 15.0), (10.0, 50.0), 5.0, &mut b);
        assert_eq!(pa, pb);
    }
}
