//! Total Processing Delay (paper Eq. 6–7).
//!
//! For aggregator `a` with processing buffer `children(a)`:
//!
//! ```text
//! d_a = (mdatasize_a + Σ_{c ∈ children(a)} mdatasize_c) / pspeed_a      (Eq. 6)
//! TPD = Σ_levels  max_{a ∈ level} d_a                                   (Eq. 7)
//! ```
//!
//! computed bottom-up over the BFT levels, exactly as the paper's
//! "Processing Fitness Function" box describes. The per-level `max`
//! captures the bottleneck effect: a level finishes only when its
//! slowest cluster does.

use super::{ChunkedFold8, ClientAttrs};
use crate::hierarchy::Arrangement;

/// Cluster delay of one aggregator slot (Eq. 6). The buffer datasizes
/// fold through [`ChunkedFold8`] — the fixed reduction order every
/// delay pipeline (scratch, delta, DES, sharded) shares, so this
/// reference stays bit-comparable to all of them.
pub fn cluster_delay(arr: &Arrangement, attrs: &[ClientAttrs], slot: usize) -> f64 {
    let agg = &attrs[arr.aggregators[slot]];
    let buffer = arr.buffer_of(slot);
    let data: f64 = agg.mdatasize + ChunkedFold8::sum(buffer.iter().map(|&c| attrs[c].mdatasize));
    data / agg.pspeed
}

/// Per-level breakdown of a TPD evaluation (kept for traces/plots).
#[derive(Debug, Clone, PartialEq)]
pub struct TpdBreakdown {
    /// Max cluster delay per level, bottom-up (leaf level first).
    pub level_max: Vec<f64>,
    /// Total processing delay (sum of `level_max`).
    pub total: f64,
}

/// Total Processing Delay of an arrangement (Eq. 7), bottom-up.
pub fn tpd(arr: &Arrangement, attrs: &[ClientAttrs]) -> TpdBreakdown {
    let mut level_max = Vec::with_capacity(arr.spec.depth);
    for level in arr.spec.levels_bottom_up() {
        let m = level
            .iter()
            .map(|&s| cluster_delay(arr, attrs, s))
            .fold(0.0_f64, f64::max);
        level_max.push(m);
    }
    let total = level_max.iter().sum();
    TpdBreakdown { level_max, total }
}

/// TPD with the memory-pressure extension (Algorithm 1 mentions
/// "compute memory consumption and delays per level"): when the data an
/// aggregator must hold exceeds its memory capacity, its cluster delay is
/// scaled by `swap_penalty` — modeling the paper's 64 MB docker
/// containers swapping while merging 30 MB JSON models. With
/// `swap_penalty = 1.0` this reduces exactly to [`tpd`].
pub fn tpd_with_memory(
    arr: &Arrangement,
    attrs: &[ClientAttrs],
    swap_penalty: f64,
) -> TpdBreakdown {
    let mut level_max = Vec::with_capacity(arr.spec.depth);
    for level in arr.spec.levels_bottom_up() {
        let mut m = 0.0_f64;
        for &s in &level {
            let agg = &attrs[arr.aggregators[s]];
            let buffer = arr.buffer_of(s);
            let data: f64 =
                agg.mdatasize + ChunkedFold8::sum(buffer.iter().map(|&c| attrs[c].mdatasize));
            let mut d = data / agg.pspeed;
            if data > agg.memcap {
                d *= swap_penalty;
            }
            m = m.max(d);
        }
        level_max.push(m);
    }
    let total = level_max.iter().sum();
    TpdBreakdown { level_max, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hierarchy::HierarchySpec;

    /// Fixed attrs: pspeed = 1 + id, mdatasize = 5, memcap = 100.
    fn attrs(n: usize) -> Vec<ClientAttrs> {
        (0..n)
            .map(|client_id| ClientAttrs {
                client_id,
                memcap: 100.0,
                mdatasize: 5.0,
                pspeed: 1.0 + client_id as f64,
            })
            .collect()
    }

    #[test]
    fn cluster_delay_eq6() {
        // depth 2, width 2: slots 0 (root), 1, 2 (leaves).
        let spec = HierarchySpec::new(2, 2);
        let a = Arrangement::from_position(spec, &[0, 1, 2], 5);
        let at = attrs(5);
        // Leaves 1, 2 get trainers 3 and 4 (round-robin): one each.
        // Slot 1 (client 1, pspeed 2): (5 + 5) / 2 = 5.
        assert!((cluster_delay(&a, &at, 1) - 5.0).abs() < 1e-12);
        // Slot 2 (client 2, pspeed 3): (5 + 5) / 3.
        assert!((cluster_delay(&a, &at, 2) - 10.0 / 3.0).abs() < 1e-12);
        // Root (client 0, pspeed 1): (5 + 5 + 5) / 1 = 15.
        assert!((cluster_delay(&a, &at, 0) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn tpd_eq7_sums_level_maxima() {
        let spec = HierarchySpec::new(2, 2);
        let a = Arrangement::from_position(spec, &[0, 1, 2], 5);
        let at = attrs(5);
        let b = tpd(&a, &at);
        // Bottom-up: leaf level max = max(5, 10/3) = 5; root = 15.
        assert_eq!(b.level_max.len(), 2);
        assert!((b.level_max[0] - 5.0).abs() < 1e-12);
        assert!((b.level_max[1] - 15.0).abs() < 1e-12);
        assert!((b.total - 20.0).abs() < 1e-12);
    }

    #[test]
    fn faster_root_lowers_tpd() {
        let spec = HierarchySpec::new(2, 2);
        let at = attrs(5);
        let slow_root = tpd(&Arrangement::from_position(spec, &[0, 1, 2], 5), &at);
        let fast_root = tpd(&Arrangement::from_position(spec, &[4, 1, 2], 5), &at);
        assert!(fast_root.total < slow_root.total);
    }

    #[test]
    fn memory_penalty_reduces_to_plain_tpd_at_one() {
        let spec = HierarchySpec::new(3, 2);
        let pos: Vec<usize> = (0..7).collect();
        let a = Arrangement::from_position(spec, &pos, 12);
        let at = attrs(12);
        let plain = tpd(&a, &at);
        let mem = tpd_with_memory(&a, &at, 1.0);
        assert_eq!(plain, mem);
    }

    #[test]
    fn memory_penalty_kicks_in_when_over_capacity() {
        let spec = HierarchySpec::new(2, 2);
        let mut at = attrs(5);
        at[0].memcap = 10.0; // root holds 15 units > 10 ⇒ swaps
        let a = Arrangement::from_position(spec, &[0, 1, 2], 5);
        let plain = tpd(&a, &at);
        let mem = tpd_with_memory(&a, &at, 4.0);
        assert!(mem.total > plain.total);
        // Only the root level got scaled: 5 + 15*4 = 65.
        assert!((mem.total - 65.0).abs() < 1e-12);
    }

    #[test]
    fn deeper_hierarchies_have_more_levels() {
        let at = attrs(100);
        for depth in 2..5 {
            let spec = HierarchySpec::new(depth, 2);
            let pos: Vec<usize> = (0..spec.dimensions()).collect();
            let a = Arrangement::from_position(spec, &pos, 100);
            assert_eq!(tpd(&a, &at).level_max.len(), depth);
        }
    }
}
