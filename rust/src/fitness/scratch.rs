//! [`TpdScratch`] — zero-allocation, delta-capable Eq. 6–7 evaluation.
//!
//! The streaming evaluation ([`TpdScratch::eval`]) reproduces
//! [`super::tpd`] bit for bit without materializing an
//! [`crate::hierarchy::Arrangement`]: the trainer partition comes from
//! the [`EvalScratch`] view (one O(clients) pass), per-leaf buffer
//! sums stream through the fixed-order [`ChunkedFold8`] reduction in
//! the same ascending order the legacy trainer lists hold (the same
//! fold [`super::tpd`] itself uses), and the per-level maxima are
//! folded in the same BFT slot order — so every intermediate float is
//! identical to the legacy pipeline's.
//!
//! On top of the cached per-slot cluster delays, two **delta**
//! evaluations score single-coordinate neighbors of the loaded
//! position without re-streaming the whole population:
//!
//! * [`TpdScratch::delta_swap`] — two slots exchange clients. The
//!   trainer partition is untouched; only the two slots and their
//!   parents change delay.
//! * [`TpdScratch::delta_replace`] — slot `k` hands its client `a` to a
//!   trainer `b`. The round-robin deal re-ranks exactly the trainers
//!   with ids strictly between `a` and `b` (their rank shifts by one,
//!   rotating them one leaf over), so only the contiguous residue run
//!   of touched leaves is re-summed — each from its cached sorted
//!   segment, in ascending id order, keeping the arithmetic bit-equal
//!   to a full evaluation.
//!
//! Both delta paths are excursions: they never mutate the cached base
//! state, which is exactly what SA / tabu / adaptive-pso probing need
//! (many neighbors of one incumbent).
//!
//! Beyond `AnalyticTpd`, the scratch doubles as the analytic mirror
//! behind the DES level-barrier delta fast path: when a barrier-mode
//! simulation's folded completion times provably equal the Eq. 6–7
//! delays bit for bit, `des::EventDrivenEnv` rebases a `TpdScratch` on
//! each full simulation and scores neighbors from it without firing a
//! single event.

use super::{ChunkedFold8, ClientAttrs};
use crate::hierarchy::{EvalScratch, HierarchySpec};
use crate::placement::PlacementError;

/// Reusable TPD evaluation state for one (spec, population) pair.
#[derive(Debug, Clone)]
pub struct TpdScratch {
    view: EvalScratch,
    /// Per-leaf trainer-datasize sums (Σ mdatasize, list order).
    leaf_sum: Vec<f64>,
    /// Per-slot cluster delays (Eq. 6) of the loaded position.
    slot_delay: Vec<f64>,
    /// Per-level maxima, bottom-up (leaf level first).
    level_max: Vec<f64>,
    /// Cached Eq. 7 total of the loaded position.
    total: f64,
    /// Delta-path overlays (never touch the base state above).
    alt_delay: Vec<f64>,
    alt_sum: Vec<f64>,
}

impl TpdScratch {
    pub fn new(spec: HierarchySpec, client_count: usize) -> TpdScratch {
        let view = EvalScratch::new(spec, client_count);
        let dims = view.dims();
        let leaf_count = view.leaf_count();
        TpdScratch {
            view,
            leaf_sum: vec![0.0; leaf_count],
            slot_delay: vec![0.0; dims],
            level_max: vec![0.0; spec.depth],
            total: f64::NAN,
            alt_delay: vec![0.0; dims],
            alt_sum: vec![0.0; leaf_count],
        }
    }

    /// Validate a candidate without disturbing the loaded base state.
    pub fn validate(&mut self, position: &[usize]) -> Result<(), PlacementError> {
        self.view.validate(position)
    }

    pub fn loaded(&self) -> bool {
        self.view.loaded()
    }

    /// The loaded base position.
    pub fn position(&self) -> &[usize] {
        self.view.position()
    }

    /// Whether `client` holds a slot in the loaded base position.
    pub fn is_aggregator(&self, client: usize) -> bool {
        self.view.is_aggregator(client)
    }

    /// Cached Eq. 7 total of the loaded base position.
    pub fn total(&self) -> f64 {
        debug_assert!(self.loaded());
        self.total
    }

    /// Full evaluation: load `position` (validating it) and compute its
    /// TPD — bit-identical to `tpd(&Arrangement::from_position(..),
    /// attrs).total`, with zero heap allocation. The position becomes
    /// the cached base for subsequent delta evaluations.
    pub fn eval(
        &mut self,
        position: &[usize],
        attrs: &[ClientAttrs],
    ) -> Result<f64, PlacementError> {
        self.view.load(position)?;
        Ok(self.compute(position, attrs))
    }

    /// [`TpdScratch::eval`] for a position that already passed
    /// [`TpdScratch::validate`] — skips the redundant re-validation the
    /// batch oracles would otherwise pay per candidate.
    pub fn eval_prevalidated(&mut self, position: &[usize], attrs: &[ClientAttrs]) -> f64 {
        self.view.load_prevalidated(position);
        self.compute(position, attrs)
    }

    /// Streaming sums/delays/maxima over the freshly-loaded view.
    fn compute(&mut self, position: &[usize], attrs: &[ClientAttrs]) -> f64 {
        debug_assert_eq!(attrs.len(), self.view.client_count());
        for i in 0..self.view.leaf_count() {
            let mut fold = ChunkedFold8::new();
            for &t in self.view.leaf_trainers(i) {
                fold.push(attrs[t].mdatasize);
            }
            self.leaf_sum[i] = fold.finish();
        }
        let spec = self.view.spec();
        let leaf_start = self.view.leaf_start();
        for slot in 0..self.view.dims() {
            let agg = &attrs[position[slot]];
            let data = if slot >= leaf_start {
                agg.mdatasize + self.leaf_sum[slot - leaf_start]
            } else {
                let mut fold = ChunkedFold8::new();
                for child in spec.children(slot) {
                    fold.push(attrs[position[child]].mdatasize);
                }
                agg.mdatasize + fold.finish()
            };
            self.slot_delay[slot] = data / agg.pspeed;
        }
        let mut total = 0.0f64;
        for (li, l) in (0..spec.depth).rev().enumerate() {
            let mut m = 0.0f64;
            for s in spec.level_slots(l) {
                m = m.max(self.slot_delay[s]);
            }
            self.level_max[li] = m;
            total += m;
        }
        self.total = total;
        total
    }

    /// Per-level maxima of the loaded base (bottom-up, leaf first).
    pub fn level_max(&self) -> &[f64] {
        debug_assert!(self.loaded());
        &self.level_max
    }

    /// Eq. 6 delay of one slot given an override of `slot_k`'s client
    /// (the only slot whose occupant a delta changes near `s`).
    fn slot_delay_with(
        &self,
        s: usize,
        attrs: &[ClientAttrs],
        slot_k: usize,
        client_k: usize,
        leaf_sum: impl Fn(usize) -> f64,
    ) -> f64 {
        let pos = self.view.position();
        let eff = |slot: usize| if slot == slot_k { client_k } else { pos[slot] };
        let agg = &attrs[eff(s)];
        let leaf_start = self.view.leaf_start();
        let data = if s >= leaf_start {
            agg.mdatasize + leaf_sum(s - leaf_start)
        } else {
            let mut fold = ChunkedFold8::new();
            for child in self.view.spec().children(s) {
                fold.push(attrs[eff(child)].mdatasize);
            }
            agg.mdatasize + fold.finish()
        };
        data / agg.pspeed
    }

    /// Sum the overlay delays exactly as the full path does.
    fn alt_total(&self) -> f64 {
        let spec = self.view.spec();
        let mut total = 0.0f64;
        for l in (0..spec.depth).rev() {
            let mut m = 0.0f64;
            for s in spec.level_slots(l) {
                m = m.max(self.alt_delay[s]);
            }
            total += m;
        }
        total
    }

    /// TPD of the base position with slots `i` and `j` exchanging
    /// clients — bit-identical to a full evaluation of the swapped
    /// position, at O(slots) cost. The base stays loaded.
    pub fn delta_swap(&mut self, i: usize, j: usize, attrs: &[ClientAttrs]) -> f64 {
        debug_assert!(self.loaded() && i != j);
        let pos = self.view.position();
        let (ci, cj) = (pos[i], pos[j]);
        let spec = self.view.spec();
        self.alt_delay.copy_from_slice(&self.slot_delay);
        // Membership and the trainer partition are unchanged; only the
        // two slots (and their parents' child sums) move.
        let mut touched = [Some(i), Some(j), spec.parent(i), spec.parent(j)];
        for t in 1..4 {
            if touched[..t].contains(&touched[t]) {
                touched[t] = None;
            }
        }
        for s in touched.into_iter().flatten() {
            // Two overridden slots: express as one override after
            // pre-resolving the other (eff computed per touched slot).
            let pos = self.view.position();
            let eff = |slot: usize| {
                if slot == i {
                    cj
                } else if slot == j {
                    ci
                } else {
                    pos[slot]
                }
            };
            let agg = &attrs[eff(s)];
            let leaf_start = self.view.leaf_start();
            let data = if s >= leaf_start {
                agg.mdatasize + self.leaf_sum[s - leaf_start]
            } else {
                let mut fold = ChunkedFold8::new();
                for child in spec.children(s) {
                    fold.push(attrs[eff(child)].mdatasize);
                }
                agg.mdatasize + fold.finish()
            };
            self.alt_delay[s] = data / agg.pspeed;
        }
        self.alt_total()
    }

    /// TPD of the base position with slot `k` handing its client to
    /// `b` (currently a trainer) — bit-identical to a full evaluation
    /// of the modified position. Only the leaves whose round-robin
    /// contents shift (the trainers with ids between the outgoing and
    /// incoming client) are re-summed. The base stays loaded.
    pub fn delta_replace(&mut self, k: usize, b: usize, attrs: &[ClientAttrs]) -> f64 {
        debug_assert!(self.loaded());
        debug_assert!(!self.view.is_aggregator(b), "replacement client must be a trainer");
        let pos = self.view.position();
        let a = pos[k];
        debug_assert_ne!(a, b);
        let leaf_count = self.view.leaf_count();
        let aggs_below = |x: usize| pos.iter().filter(|&&p| p < x).count();
        // Trainer ranks in the *base* deal: `a` would insert at r_a,
        // `b` currently holds r_b.
        let r_a = a - aggs_below(a);
        let r_b = b - aggs_below(b);
        // The contiguous residue run of leaves whose contents change.
        let (run_start, run_len) = if a < b {
            (r_a % leaf_count, (r_b - r_a + 1).min(leaf_count))
        } else {
            (r_b % leaf_count, (r_a - r_b).min(leaf_count))
        };
        for t in 0..run_len {
            let i = (run_start + t) % leaf_count;
            // Re-stream leaf i's post-change contents in ascending id
            // order — unchanged prefix, the incoming client, the
            // trainers rotating in from the neighboring leaf, the
            // unchanged suffix — into a fresh fold: same sequence as
            // a full pass over the modified position, so the chunked
            // reduction lands every element on the same lane and the
            // sum comes out bit-identical.
            let seg = self.view.leaf_trainers(i);
            let mut fold = ChunkedFold8::new();
            if a < b {
                // prefix: ids < a stayed on leaf i
                for &c in &seg[..seg.partition_point(|&c| c < a)] {
                    fold.push(attrs[c].mdatasize);
                }
                if r_a % leaf_count == i {
                    fold.push(attrs[a].mdatasize);
                }
                // mid: ids in (a, b) rotated in from leaf i−1
                let prev = self.view.leaf_trainers((i + leaf_count - 1) % leaf_count);
                let mid =
                    &prev[prev.partition_point(|&c| c <= a)..prev.partition_point(|&c| c < b)];
                for &c in mid {
                    fold.push(attrs[c].mdatasize);
                }
                // suffix: ids > b stayed on leaf i
                for &c in &seg[seg.partition_point(|&c| c <= b)..] {
                    fold.push(attrs[c].mdatasize);
                }
            } else {
                // prefix: ids < b stayed on leaf i
                for &c in &seg[..seg.partition_point(|&c| c < b)] {
                    fold.push(attrs[c].mdatasize);
                }
                // mid: ids in (b, a) rotated in from leaf i+1
                let next = self.view.leaf_trainers((i + 1) % leaf_count);
                let mid =
                    &next[next.partition_point(|&c| c <= b)..next.partition_point(|&c| c < a)];
                for &c in mid {
                    fold.push(attrs[c].mdatasize);
                }
                if (r_a - 1) % leaf_count == i {
                    fold.push(attrs[a].mdatasize);
                }
                // suffix: ids > a stayed on leaf i
                for &c in &seg[seg.partition_point(|&c| c <= a)..] {
                    fold.push(attrs[c].mdatasize);
                }
            }
            self.alt_sum[i] = fold.finish();
        }
        // Patch the affected slot delays over the cached base.
        self.alt_delay.copy_from_slice(&self.slot_delay);
        let leaf_start = self.view.leaf_start();
        let in_run = |i: usize| {
            run_len == leaf_count || (i + leaf_count - run_start) % leaf_count < run_len
        };
        for t in 0..run_len {
            let i = (run_start + t) % leaf_count;
            let alt = self.alt_sum[i];
            let d = self.slot_delay_with(leaf_start + i, attrs, k, b, |leaf| {
                debug_assert_eq!(leaf, i);
                alt
            });
            self.alt_delay[leaf_start + i] = d;
        }
        // Slot k itself (new aggregator b): if it is a leaf outside the
        // run its sum is the cached one; if inner, re-fold its children.
        if k < leaf_start || !in_run(k - leaf_start) {
            let d = self.slot_delay_with(k, attrs, k, b, |leaf| self.leaf_sum[leaf]);
            self.alt_delay[k] = d;
        }
        // Parent of k: its child-datasize fold now includes b.
        if let Some(p) = self.view.spec().parent(k) {
            let d = self.slot_delay_with(p, attrs, k, b, |leaf| self.leaf_sum[leaf]);
            self.alt_delay[p] = d;
        }
        self.alt_total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::tpd;
    use crate::hierarchy::Arrangement;
    use crate::prng::{Pcg32, Rng};

    fn population(n: usize, seed: u64) -> Vec<ClientAttrs> {
        let mut rng = Pcg32::seed_from_u64(seed);
        // Distinct mdatasize per client so partition mistakes cannot
        // cancel out in the sums.
        let mut attrs =
            ClientAttrs::sample_population(n, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
        for a in attrs.iter_mut() {
            a.mdatasize = rng.uniform(1.0, 9.0);
        }
        attrs
    }

    fn reference(spec: HierarchySpec, pos: &[usize], attrs: &[ClientAttrs]) -> f64 {
        tpd(&Arrangement::from_position(spec, pos, attrs.len()), attrs).total
    }

    #[test]
    fn eval_is_bit_identical_to_legacy_tpd() {
        let mut rng = Pcg32::seed_from_u64(3);
        for (d, w, cc) in [(1, 1, 6), (2, 2, 9), (3, 2, 30), (3, 4, 53), (2, 5, 80)] {
            let spec = HierarchySpec::new(d, w);
            let mut scratch = TpdScratch::new(spec, cc);
            let attrs = population(cc, 100 + cc as u64);
            for _ in 0..20 {
                let pos = rng.sample_distinct(cc, spec.dimensions());
                let fast = scratch.eval(&pos, &attrs).unwrap();
                let slow = reference(spec, &pos, &attrs);
                assert_eq!(fast.to_bits(), slow.to_bits(), "D{d} W{w} cc{cc} {pos:?}");
                assert_eq!(scratch.total().to_bits(), slow.to_bits());
            }
        }
    }

    #[test]
    fn delta_replace_is_bit_identical_to_full_eval() {
        let mut rng = Pcg32::seed_from_u64(8);
        for (d, w, cc) in [(2, 2, 9), (3, 2, 31), (3, 4, 90), (1, 1, 12), (2, 4, 70)] {
            let spec = HierarchySpec::new(d, w);
            let dims = spec.dimensions();
            let mut scratch = TpdScratch::new(spec, cc);
            let attrs = population(cc, 7 * cc as u64);
            for _ in 0..30 {
                let pos = rng.sample_distinct(cc, dims);
                scratch.eval(&pos, &attrs).unwrap();
                let k = rng.gen_range(dims as u64) as usize;
                let mut b = rng.gen_range(cc as u64) as usize;
                while pos.contains(&b) {
                    b = (b + 1) % cc;
                }
                let fast = scratch.delta_replace(k, b, &attrs);
                let mut neighbor = pos.clone();
                neighbor[k] = b;
                let slow = reference(spec, &neighbor, &attrs);
                assert_eq!(
                    fast.to_bits(),
                    slow.to_bits(),
                    "D{d} W{w} cc{cc} k{k} {}→{b}: {fast} vs {slow}",
                    pos[k]
                );
                // The excursion must not disturb the base.
                assert_eq!(scratch.total().to_bits(), reference(spec, &pos, &attrs).to_bits());
            }
        }
    }

    #[test]
    fn delta_swap_is_bit_identical_to_full_eval() {
        let mut rng = Pcg32::seed_from_u64(21);
        for (d, w, cc) in [(2, 2, 9), (3, 3, 40), (4, 2, 30)] {
            let spec = HierarchySpec::new(d, w);
            let dims = spec.dimensions();
            let mut scratch = TpdScratch::new(spec, cc);
            let attrs = population(cc, 11 * cc as u64);
            for _ in 0..30 {
                let pos = rng.sample_distinct(cc, dims);
                scratch.eval(&pos, &attrs).unwrap();
                let i = rng.gen_range(dims as u64) as usize;
                let mut j = rng.gen_range(dims as u64) as usize;
                while j == i {
                    j = rng.gen_range(dims as u64) as usize;
                }
                let fast = scratch.delta_swap(i, j, &attrs);
                let mut neighbor = pos.clone();
                neighbor.swap(i, j);
                let slow = reference(spec, &neighbor, &attrs);
                assert_eq!(fast.to_bits(), slow.to_bits(), "D{d} W{w} swap {i}<->{j}");
            }
        }
    }

    #[test]
    fn adjacent_replacement_touches_one_leaf() {
        // a and b adjacent in id space: the rank shift is empty and the
        // run collapses to (at most) the entry/exit leaf.
        let spec = HierarchySpec::new(2, 2);
        let cc = 11;
        let attrs = population(cc, 5);
        let mut scratch = TpdScratch::new(spec, cc);
        let pos = vec![4, 7, 9];
        scratch.eval(&pos, &attrs).unwrap();
        for (k, b) in [(0usize, 3usize), (0, 5), (1, 6), (1, 8), (2, 10), (2, 8)] {
            let fast = scratch.delta_replace(k, b, &attrs);
            let mut neighbor = pos.clone();
            neighbor[k] = b;
            assert_eq!(fast.to_bits(), reference(spec, &neighbor, &attrs).to_bits());
        }
    }
}
