//! Fixed-order chunked pairwise reduction — the one summation shape
//! every delay pipeline folds datasizes with.
//!
//! The repo's bit-exactness contract ("every path returns the same
//! bits") makes the *reduction order* part of the API: a serial left
//! fold, a delta re-sum, and a sharded worker must all combine the
//! same elements in the same order or their floats drift. PR 8's
//! follow-up asked for a SIMD-friendly fold that keeps that order
//! fixed; this module is it.
//!
//! [`ChunkedFold8`] streams elements into 8 accumulator lanes
//! round-robin (`lanes[i % 8] += x_i`) and combines them pairwise in
//! one fixed tree:
//!
//! ```text
//! total = ((l0+l1) + (l2+l3)) + ((l4+l5) + (l6+l7))
//! ```
//!
//! The 8 independent lanes break the serial add dependency chain, so
//! the compiler can keep several FP adds in flight (and vectorize the
//! lane updates where the loads allow); the combine tree is a fixed
//! expression, so the result is a pure function of the element
//! *sequence* — independent of which code path streamed it, which
//! thread ran it, or whether the elements came from a full pass or a
//! delta re-sum. That sequence contract is what the scratch delta
//! paths and the sharded optimizer lean on: they re-stream a leaf's
//! post-change contents in the same ascending-id order the full pass
//! uses, and the fold guarantees the same bits.
//!
//! [`linear_sum`] keeps the legacy left fold as the in-tree reference
//! oracle: property tests assert the chunked fold stays within float
//! noise of it on random streams and exactly equals it for short
//! streams (n ≤ 3 touches only the first combine pair).

/// Streaming 8-lane chunked pairwise reduction with a fixed combine
/// order. `Default`-constructible, `Copy`-cheap, no heap.
#[derive(Debug, Clone, Copy)]
pub struct ChunkedFold8 {
    lanes: [f64; 8],
    n: usize,
}

impl ChunkedFold8 {
    #[inline]
    pub fn new() -> ChunkedFold8 {
        ChunkedFold8 { lanes: [0.0; 8], n: 0 }
    }

    /// Stream the next element; lane = element index mod 8.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.lanes[self.n & 7] += x;
        self.n += 1;
    }

    /// Elements streamed so far.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Combine the lanes in the fixed pairwise order. Pure — the fold
    /// can keep streaming afterwards.
    #[inline]
    pub fn finish(&self) -> f64 {
        let l = &self.lanes;
        ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
    }

    /// Fold an iterator in stream order.
    #[inline]
    pub fn sum(values: impl IntoIterator<Item = f64>) -> f64 {
        let mut fold = ChunkedFold8::new();
        for x in values {
            fold.push(x);
        }
        fold.finish()
    }
}

impl Default for ChunkedFold8 {
    fn default() -> ChunkedFold8 {
        ChunkedFold8::new()
    }
}

/// The legacy strict left fold (`((x0 + x1) + x2) + …`), retained as
/// the reference oracle the chunked fold is property-tested against.
#[inline]
pub fn linear_sum(values: impl IntoIterator<Item = f64>) -> f64 {
    let mut acc = 0.0f64;
    for x in values {
        acc += x;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Pcg32, Rng};

    #[test]
    fn empty_and_single_streams() {
        assert_eq!(ChunkedFold8::sum([]).to_bits(), 0.0f64.to_bits());
        assert_eq!(ChunkedFold8::sum([3.25]).to_bits(), 3.25f64.to_bits());
    }

    #[test]
    fn short_streams_equal_linear_fold_exactly() {
        // n ≤ 3 only ever touches lanes 0..=2, so the combine tree
        // degenerates to the left fold (plus exact +0.0 terms): the
        // hand-computed expectations in the tpd unit tests stay valid.
        let mut rng = Pcg32::seed_from_u64(17);
        for _ in 0..200 {
            let n = rng.gen_range(4) as usize; // 0..=3
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 9.0)).collect();
            let chunked = ChunkedFold8::sum(xs.iter().copied());
            let linear = linear_sum(xs.iter().copied());
            assert_eq!(chunked.to_bits(), linear.to_bits(), "{xs:?}");
        }
    }

    #[test]
    fn streaming_matches_one_shot_sum() {
        let mut rng = Pcg32::seed_from_u64(23);
        let xs: Vec<f64> = (0..137).map(|_| rng.uniform(0.0, 50.0)).collect();
        let mut fold = ChunkedFold8::new();
        for &x in &xs {
            fold.push(x);
        }
        assert_eq!(fold.len(), xs.len());
        assert_eq!(fold.finish().to_bits(), ChunkedFold8::sum(xs.iter().copied()).to_bits());
    }

    #[test]
    fn chunked_stays_within_float_noise_of_linear() {
        let mut rng = Pcg32::seed_from_u64(41);
        for _ in 0..50 {
            let n = 1 + rng.gen_range(400) as usize;
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 100.0)).collect();
            let chunked = ChunkedFold8::sum(xs.iter().copied());
            let linear = linear_sum(xs.iter().copied());
            let rel = (chunked - linear).abs() / linear.max(1e-12);
            assert!(rel < 1e-12, "n={n} chunked={chunked} linear={linear}");
        }
    }

    #[test]
    fn result_is_a_pure_function_of_the_stream() {
        // Two independently-constructed folds over the same sequence —
        // as a delta re-sum and a full pass would build them — agree
        // bitwise, and restarting mid-way (fresh fold, same tail) does
        // not: the order contract is positional, not set-based.
        let mut rng = Pcg32::seed_from_u64(59);
        let xs: Vec<f64> = (0..99).map(|_| rng.uniform(0.5, 4.0)).collect();
        let a = ChunkedFold8::sum(xs.iter().copied());
        let b = ChunkedFold8::sum(xs.iter().copied());
        assert_eq!(a.to_bits(), b.to_bits());
        let mut rev = xs.clone();
        rev.reverse();
        // Reordering the stream is allowed to (and generally does)
        // change the low bits — which is exactly why every pipeline
        // must stream in the same ascending order.
        let c = ChunkedFold8::sum(rev.into_iter());
        let rel = (a - c).abs() / a.abs().max(1e-12);
        assert!(rel < 1e-12);
    }
}
