//! The paper's fitness model: Total Processing Delay over an arrangement.
//!
//! * [`ClientAttrs`] — the simulated per-client attributes of §IV.A
//!   (memory capacity, model data size, processing speed).
//! * [`tpd`] — Eq. 6/7: per-aggregator cluster delay, per-level max,
//!   summed bottom-up; plus the optional memory-pressure extension used
//!   by the deployment emulation.

mod client_attrs;
mod tpd;

pub use client_attrs::ClientAttrs;
pub use tpd::{cluster_delay, tpd, tpd_with_memory, TpdBreakdown};
