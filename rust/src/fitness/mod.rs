//! The paper's fitness model: Total Processing Delay over an arrangement.
//!
//! * [`ClientAttrs`] — the simulated per-client attributes of §IV.A
//!   (memory capacity, model data size, processing speed).
//! * [`tpd`] — Eq. 6/7: per-aggregator cluster delay, per-level max,
//!   summed bottom-up; plus the optional memory-pressure extension used
//!   by the deployment emulation. This is the *reference* path: it
//!   materializes an [`crate::hierarchy::Arrangement`] per call.
//! * [`TpdScratch`] — the zero-allocation evaluation core the delay
//!   oracles run on: the same Eq. 6/7 arithmetic streamed over an
//!   [`crate::hierarchy::EvalScratch`] view (bit-identical to [`tpd`],
//!   property-tested), plus one-swap **delta** evaluations that
//!   rescore a single-coordinate neighbor from the cached per-slot
//!   delays. See the module docs in [`crate::hierarchy`] for why the
//!   streaming trainer partition is equivalent to the paper's
//!   buffer-of-available-labels semantics.

mod client_attrs;
mod fold;
mod scratch;
mod tpd;

pub use client_attrs::ClientAttrs;
pub use fold::{linear_sum, ChunkedFold8};
pub use scratch::TpdScratch;
pub use tpd::{cluster_delay, tpd, tpd_with_memory, TpdBreakdown};
