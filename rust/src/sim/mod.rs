//! Fig-3 simulator: PSO aggregation placement over simulated clients
//! (paper §IV.A/B).
//!
//! Builds a client population with the paper's attribute distributions,
//! runs the synchronous [`crate::pso::Swarm`] against the Eq. 6–7 TPD
//! fitness, and records the per-iteration traces (per-particle TPD +
//! worst/mean/best) that the paper plots.

mod fig4;
mod plot;
mod runner;
mod trace;

pub use fig4::{
    report_fig4, run_e2e, run_fig4_comparison, run_live_comparison, run_strategy,
    LiveServiceOptions, StrategyOutcome, DEFAULT_STRATEGIES,
};
pub use plot::ascii_plot;
pub use runner::{run_sim, run_sim_in, run_sim_with, SimResult};
pub use trace::SimTrace;
