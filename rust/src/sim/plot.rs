//! Terminal ASCII rendering of Fig-3-style convergence curves, so the
//! examples/benches can show the paper's plots without a plotting stack.

/// Render series as an ASCII chart. Each `(label, glyph, series)` is
/// drawn with its glyph; later series overdraw earlier ones.
pub fn ascii_plot(
    title: &str,
    series: &[(&str, char, &[f64])],
    width: usize,
    height: usize,
) -> String {
    assert!(width >= 8 && height >= 4);
    let n = series.iter().map(|(_, _, s)| s.len()).max().unwrap_or(0);
    if n == 0 {
        return format!("{title}\n(empty)\n");
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (_, _, s) in series {
        for &v in *s {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        return format!("{title}\n(no finite data)\n");
    }
    if (hi - lo).abs() < 1e-12 {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (_, glyph, s) in series {
        for (i, &v) in s.iter().enumerate() {
            if !v.is_finite() {
                continue;
            }
            let x = if n == 1 { 0 } else { i * (width - 1) / (n - 1) };
            let yf = (v - lo) / (hi - lo);
            let y = ((1.0 - yf) * (height - 1) as f64).round() as usize;
            grid[y.min(height - 1)][x] = *glyph;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (r, row) in grid.iter().enumerate() {
        let yval = hi - (hi - lo) * r as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:>9.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>11}0{:>w$}\n", "", n - 1, w = width - 1));
    let legend: Vec<String> = series
        .iter()
        .map(|(label, glyph, _)| format!("{glyph}={label}"))
        .collect();
    out.push_str(&format!("{:>11}{}\n", "", legend.join("  ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_monotone_series() {
        let s: Vec<f64> = (0..50).map(|i| 10.0 - 0.1 * i as f64).collect();
        let out = ascii_plot("test", &[("tpd", '*', &s)], 40, 10);
        assert!(out.contains("test"));
        assert!(out.contains('*'));
        assert!(out.contains("*=tpd"));
        // First grid row (max value) should contain the start of the series.
        let first_row = out.lines().nth(1).unwrap();
        assert!(first_row.contains('*'));
    }

    #[test]
    fn constant_series_does_not_panic() {
        let s = vec![5.0; 10];
        let out = ascii_plot("const", &[("x", 'x', &s)], 20, 5);
        assert!(out.contains('x'));
    }

    #[test]
    fn empty_series_handled() {
        let out = ascii_plot("none", &[("x", 'x', &[])], 20, 5);
        assert!(out.contains("empty"));
    }

    #[test]
    fn multiple_series_all_legended() {
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![3.0, 2.0, 1.0];
        let out = ascii_plot("two", &[("up", 'u', &a), ("down", 'd', &b)], 20, 6);
        assert!(out.contains("u=up"));
        assert!(out.contains("d=down"));
    }
}
