//! Simulation driver: scenario → population → PSO → trace.

use super::SimTrace;
use crate::configio::SimScenario;
use crate::fitness::{tpd, ClientAttrs};
use crate::hierarchy::{Arrangement, HierarchySpec};
use crate::prng::Pcg32;
use crate::pso::Swarm;

/// Output of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub scenario: SimScenario,
    pub trace: SimTrace,
    /// Best placement found (client ids per slot).
    pub best_placement: Vec<usize>,
    /// TPD of `best_placement`.
    pub best_tpd: f64,
    /// Whether all particles converged to one placement (the paper's
    /// convergence criterion).
    pub converged: bool,
    /// The simulated client population (for inspection / plots).
    pub attrs: Vec<ClientAttrs>,
}

/// Run the Fig-3 simulation for one scenario.
pub fn run_sim(scenario: &SimScenario) -> SimResult {
    let spec = HierarchySpec::new(scenario.depth, scenario.width);
    let dims = spec.dimensions();
    let client_count = scenario.client_count();

    let mut rng = Pcg32::seed_from_u64(scenario.seed);
    let attrs = ClientAttrs::sample_population(
        client_count,
        scenario.pspeed_range,
        scenario.memcap_range,
        scenario.mdatasize,
        &mut rng,
    );

    let mut swarm = Swarm::new(dims, client_count, scenario.pso, rng.split());
    let stats = swarm.run(|pos| tpd(&Arrangement::from_position(spec, pos, client_count), &attrs).total);

    let trace = SimTrace::from_stats(&stats);
    SimResult {
        scenario: scenario.clone(),
        best_placement: swarm.gbest_placement(),
        best_tpd: -swarm.gbest_fitness,
        converged: swarm.converged(),
        trace,
        attrs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_scenario() -> SimScenario {
        let mut sc = SimScenario {
            depth: 3,
            width: 2,
            ..SimScenario::default()
        };
        sc.pso.iterations = 60;
        sc.pso.particles = 5;
        sc
    }

    #[test]
    fn sim_improves_tpd() {
        let r = run_sim(&quick_scenario());
        let first_mean = r.trace.mean[0];
        assert!(
            r.best_tpd < first_mean,
            "best {} should beat initial mean {}",
            r.best_tpd,
            first_mean
        );
    }

    #[test]
    fn best_placement_is_valid_and_matches_tpd() {
        let sc = quick_scenario();
        let r = run_sim(&sc);
        let spec = HierarchySpec::new(sc.depth, sc.width);
        assert_eq!(r.best_placement.len(), spec.dimensions());
        let recomputed = tpd(
            &Arrangement::from_position(spec, &r.best_placement, sc.client_count()),
            &r.attrs,
        )
        .total;
        assert!((recomputed - r.best_tpd).abs() < 1e-9);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run_sim(&quick_scenario());
        let b = run_sim(&quick_scenario());
        assert_eq!(a.best_placement, b.best_placement);
        assert_eq!(a.trace.mean, b.trace.mean);
    }

    #[test]
    fn trace_lengths_match_iterations() {
        let sc = quick_scenario();
        let r = run_sim(&sc);
        assert_eq!(r.trace.iterations(), sc.pso.iterations);
        assert_eq!(r.trace.per_particle.len(), sc.pso.particles);
    }

    #[test]
    fn larger_swarm_not_worse() {
        // Paper's observation: more particles find equal-or-better
        // placements (Fig. 3 (a) vs (d)). Allow small tolerance since
        // this is stochastic.
        let mut small = quick_scenario();
        small.pso.particles = 2;
        small.pso.iterations = 100;
        let mut large = quick_scenario();
        large.pso.particles = 10;
        large.pso.iterations = 100;
        let r_small = run_sim(&small);
        let r_large = run_sim(&large);
        assert!(r_large.best_tpd <= r_small.best_tpd * 1.05);
    }
}
