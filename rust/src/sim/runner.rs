//! Simulation driver: scenario → population → optimizer × environment →
//! trace. A `repro sim` run is a one-cell experiment: the trial is
//! executed by [`crate::exp::run_cell_trial`] on a
//! [`crate::exp::TrialScheduler`] — the same code path `repro fleet`,
//! `repro compare` and `repro ablate` schedule at scale — and `"pso"`
//! replays the paper's Algorithm 1 exactly (same seed ⇒ same trace as
//! the original closure-driven `run_sim`).

use super::SimTrace;
use crate::configio::SimScenario;
use crate::exp::{run_cell_trial, TrialScheduler};
use crate::fitness::ClientAttrs;
use crate::placement::PlacementError;

/// Output of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub scenario: SimScenario,
    /// Canonical strategy name the run used.
    pub strategy: String,
    pub trace: SimTrace,
    /// Best placement found (client ids per slot).
    pub best_placement: Vec<usize>,
    /// TPD of `best_placement`.
    pub best_tpd: f64,
    /// Whether the optimizer reports convergence (for PSO: all particles
    /// propose one placement — the paper's criterion).
    pub converged: bool,
    /// The simulated client population (for inspection / plots).
    pub attrs: Vec<ClientAttrs>,
    /// Fitness evaluations spent (= iterations × particles).
    pub evaluations: usize,
}

/// Run one simulation with any registered strategy against any
/// registered simulation-tier environment (`analytic` or
/// `event-driven`), under the scenario's evaluation budget
/// (`pso.iterations × pso.particles`, the same budget the paper's swarm
/// spends).
pub fn run_sim_in(
    scenario: &SimScenario,
    strategy: &str,
    env_name: &str,
) -> Result<SimResult, PlacementError> {
    // One-cell experiment: a single trial scheduled like any fleet
    // replicate. `run_cell_trial` keeps the legacy seeding discipline
    // (population sampled from `scenario.seed`, the optimizer stream
    // split off after), so PSO runs reproduce the original pipeline.
    let mut results = TrialScheduler::new(1)
        .run(1, |_| run_cell_trial(scenario, strategy, env_name, None, true));
    let t = results.pop().expect("one-cell plan yields one trial")?;
    let (best_placement, best_tpd) = match t.opt_best {
        Some((p, d)) => (p.into_vec(), d),
        None => (
            t.drive_best_placement.map(|p| p.into_vec()).unwrap_or_default(),
            t.best_delay,
        ),
    };
    Ok(SimResult {
        scenario: scenario.clone(),
        strategy: t.strategy,
        trace: SimTrace::from_stats(&t.stats),
        best_placement,
        best_tpd,
        converged: t.converged,
        attrs: t.attrs,
        evaluations: t.evaluations,
    })
}

/// Run one simulation with any registered strategy against the
/// scenario's configured environment (`sim.env`, `analytic` unless the
/// scenario says otherwise).
pub fn run_sim_with(scenario: &SimScenario, strategy: &str) -> Result<SimResult, PlacementError> {
    run_sim_in(scenario, strategy, &scenario.env)
}

/// Run the Fig-3 simulation for one scenario with the paper's PSO.
pub fn run_sim(scenario: &SimScenario) -> SimResult {
    run_sim_with(scenario, "pso").expect("pso is always registered")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hierarchy::HierarchySpec;
    use crate::placement::registry;
    use crate::prng::Pcg32;

    fn quick_scenario() -> SimScenario {
        let mut sc = SimScenario {
            depth: 3,
            width: 2,
            ..SimScenario::default()
        };
        sc.pso.iterations = 60;
        sc.pso.particles = 5;
        sc
    }

    #[test]
    fn sim_improves_tpd() {
        let r = run_sim(&quick_scenario());
        let first_mean = r.trace.mean[0];
        assert!(
            r.best_tpd < first_mean,
            "best {} should beat initial mean {}",
            r.best_tpd,
            first_mean
        );
    }

    #[test]
    fn best_placement_is_valid_and_matches_tpd() {
        use crate::fitness::tpd;
        use crate::hierarchy::Arrangement;
        let sc = quick_scenario();
        let r = run_sim(&sc);
        let spec = HierarchySpec::new(sc.depth, sc.width);
        assert_eq!(r.best_placement.len(), spec.dimensions());
        let recomputed = tpd(
            &Arrangement::from_position(spec, &r.best_placement, sc.client_count()),
            &r.attrs,
        )
        .total;
        assert!((recomputed - r.best_tpd).abs() < 1e-9);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run_sim(&quick_scenario());
        let b = run_sim(&quick_scenario());
        assert_eq!(a.best_placement, b.best_placement);
        assert_eq!(a.trace.mean, b.trace.mean);
    }

    #[test]
    fn trace_lengths_match_iterations() {
        let sc = quick_scenario();
        let r = run_sim(&sc);
        assert_eq!(r.trace.iterations(), sc.pso.iterations);
        assert_eq!(r.trace.per_particle.len(), sc.pso.particles);
        assert_eq!(r.evaluations, sc.pso.iterations * sc.pso.particles);
    }

    #[test]
    fn larger_swarm_not_worse() {
        // Paper's observation: more particles find equal-or-better
        // placements (Fig. 3 (a) vs (d)). Allow small tolerance since
        // this is stochastic.
        let mut small = quick_scenario();
        small.pso.particles = 2;
        small.pso.iterations = 100;
        let mut large = quick_scenario();
        large.pso.particles = 10;
        large.pso.iterations = 100;
        let r_small = run_sim(&small);
        let r_large = run_sim(&large);
        assert!(r_large.best_tpd <= r_small.best_tpd * 1.05);
    }

    #[test]
    fn every_registered_strategy_runs_the_quick_scenario() {
        let sc = quick_scenario();
        for name in registry::NAMES {
            let r = run_sim_with(&sc, name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(r.strategy, name);
            assert_eq!(r.evaluations, sc.pso.iterations * sc.pso.particles);
            assert!(r.best_tpd.is_finite() && r.best_tpd > 0.0, "{name}: {}", r.best_tpd);
            assert_eq!(r.best_placement.len(), sc.dimensions());
            // Traces are plottable for every strategy.
            assert!(r.trace.iterations() > 0);
        }
    }

    #[test]
    fn unknown_strategy_is_a_helpful_error() {
        let err = run_sim_with(&quick_scenario(), "annealing").unwrap_err();
        assert!(err.to_string().contains("valid strategies"), "{err}");
    }

    #[test]
    fn unknown_environment_is_a_helpful_error() {
        let err = run_sim_in(&quick_scenario(), "pso", "docker").unwrap_err();
        assert!(err.to_string().contains("valid environments"), "{err}");
    }

    #[test]
    fn event_driven_env_is_selectable_everywhere_analytic_is() {
        // `sim.env = "des"` (alias) routes the whole pipeline through the
        // discrete-event oracle; in the default (conformance) scenario
        // configuration its scores are the analytic Eq. 6–7 TPD, so the
        // best placement's recomputed TPD matches the reported delay.
        use crate::fitness::tpd;
        use crate::hierarchy::Arrangement;
        let mut sc = quick_scenario();
        sc.env = "des".to_string();
        for name in ["pso", "ga", "random"] {
            let r = run_sim_with(&sc, name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(r.evaluations, sc.pso.iterations * sc.pso.particles);
            let spec = HierarchySpec::new(sc.depth, sc.width);
            let recomputed = tpd(
                &Arrangement::from_position(spec, &r.best_placement, sc.client_count()),
                &r.attrs,
            )
            .total;
            assert!(
                (recomputed - r.best_tpd).abs() < 1e-9,
                "{name}: des best {} != analytic recompute {recomputed}",
                r.best_tpd
            );
        }
    }

    #[test]
    fn registry_pso_reproduces_the_legacy_swarm_pipeline() {
        // The acceptance check for the API swap: the registry-driven
        // `"pso"` path must equal a hand-built Swarm driven by the
        // original closure loop, seed for seed.
        use crate::fitness::tpd;
        use crate::hierarchy::Arrangement;
        use crate::pso::Swarm;
        let sc = quick_scenario();
        let spec = HierarchySpec::new(sc.depth, sc.width);
        let cc = sc.client_count();
        let mut rng = Pcg32::seed_from_u64(sc.seed);
        let attrs = ClientAttrs::sample_population(
            cc,
            sc.pspeed_range,
            sc.memcap_range,
            sc.mdatasize,
            &mut rng,
        );
        let mut swarm = Swarm::new(spec.dimensions(), cc, sc.pso, rng.split());
        let stats = swarm.run(|pos| {
            tpd(&Arrangement::from_position(spec, pos, cc), &attrs).total
        });
        let legacy_trace = SimTrace::from_stats(&stats);
        let legacy_best = -swarm.gbest_fitness;

        let r = run_sim_with(&sc, "pso").unwrap();
        assert_eq!(r.trace.per_particle, legacy_trace.per_particle);
        assert_eq!(r.trace.gbest, legacy_trace.gbest);
        assert_eq!(r.trace.mean, legacy_trace.mean);
        assert_eq!(r.best_placement, swarm.gbest_placement());
        assert!((r.best_tpd - legacy_best).abs() < 1e-12);
        assert_eq!(r.converged, swarm.converged());
    }
}
