//! Fig-4 harness: the docker-analogue deployment comparison (random vs
//! uniform round-robin vs PSO, plus any other registered strategy) and
//! the end-to-end training driver. Shared by `repro compare` /
//! `repro e2e`, the examples and the `fig4_deploy` bench so every entry
//! point reports identical rows. Strategies are built through
//! [`registry`], so `--strategies ga,sa,tabu` works everywhere.
//!
//! The comparison itself runs through the service tier
//! ([`crate::service`]): each strategy × replicate pair is one live
//! session submitted to a [`CoordinatorService`], which multiplexes the
//! sessions over one shared broker, persists them through the
//! configured [`Store`] and streams events into the configured metric
//! sink — so `--replicates R` means R independently seeded FL sessions
//! per strategy, not a re-scored trace.

use super::ascii_plot;
use crate::configio::{DeployScenario, DynamicsSpec};
use crate::exp::replicate_seed;
use crate::fl::Deployment;
use crate::metrics::{mean_ci, CsvWriter, RoundRecord, RoundRecorder};
use crate::placement::registry;
use crate::runtime::ModelRuntime;
use crate::service::{
    CoordinatorService, CsvRecorder, NoopRecorder, NoopStore, Phase, Recorder, ServiceConfig,
    SessionSpec, Store,
};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// The paper's Fig-4 strategy line-up (seed-compatible labels: the
/// round-robin baseline keeps its paper name "uniform").
pub const DEFAULT_STRATEGIES: [&str; 3] = ["random", "uniform", "pso"];

/// Outcome of one strategy's deployment run.
pub struct StrategyOutcome {
    /// The requested strategy name (alias preserved for CSV headers).
    pub name: String,
    pub recorder: RoundRecorder,
}

/// Run one strategy (any [`registry`] name or alias) through a full
/// deployment.
pub fn run_strategy(
    sc: &DeployScenario,
    name: &str,
    runtime: Arc<ModelRuntime>,
    time_scale: f64,
) -> Result<StrategyOutcome> {
    let optimizer =
        registry::build_live(name, sc.dimensions(), sc.clients.len(), sc.pso, sc.seed ^ 0xABCD)
            .map_err(|e| anyhow!(e))?;
    let session = format!("fig4-{name}");
    let mut dep = Deployment::launch(sc, &session, runtime, optimizer, time_scale)?;
    dep.run(sc.rounds)?;
    let recorder = dep.coordinator.recorder().clone();
    dep.shutdown();
    Ok(StrategyOutcome { name: name.to_string(), recorder })
}

/// Knobs for the service-backed live comparison. The default is one
/// replicate per strategy, one worker per core, static membership, no
/// persistence and no metric sink — the classic `repro compare` run.
pub struct LiveServiceOptions {
    /// Independent sessions per strategy; seeds derived with
    /// [`replicate_seed`] from the deploy scenario's seed.
    pub replicates: usize,
    /// Service worker threads (0 = one per available core).
    pub threads: usize,
    /// Membership dynamics replayed into every session (`--dynamics`).
    pub dynamics: Option<DynamicsSpec>,
    /// Session persistence backend (resume-aware).
    pub store: Arc<dyn Store>,
    /// Service event CSV (`None` = discard events).
    pub metrics_path: Option<PathBuf>,
}

impl Default for LiveServiceOptions {
    fn default() -> Self {
        LiveServiceOptions {
            replicates: 1,
            threads: 0,
            dynamics: None,
            store: Arc::new(NoopStore::new()),
            metrics_path: None,
        }
    }
}

/// The full Fig-4 comparison over `strategies` (registry names; empty ⇒
/// the paper's default trio) with default service options. Writes
/// `results/fig4.csv` (per-round delays per strategy) and prints the
/// paper-style summary (totals, convergence round, percentage
/// improvements).
pub fn run_fig4_comparison(
    rounds: usize,
    time_scale: f64,
    out_dir: &Path,
    strategies: &[String],
) -> Result<()> {
    run_live_comparison(rounds, time_scale, out_dir, strategies, &LiveServiceOptions::default())
}

/// Service-backed live comparison: one [`SessionSpec`] per strategy ×
/// replicate, all multiplexed by a [`CoordinatorService`] over one
/// shared broker. Replicate 0 of each strategy feeds the classic Fig-4
/// CSV/plot; with `--replicates R > 1` the per-strategy total delays
/// additionally get a mean ± 95% CI table.
pub fn run_live_comparison(
    rounds: usize,
    time_scale: f64,
    out_dir: &Path,
    strategies: &[String],
    opts: &LiveServiceOptions,
) -> Result<()> {
    if opts.replicates == 0 {
        return Err(anyhow!("--replicates must be >= 1"));
    }
    let runtime = Arc::new(
        ModelRuntime::load_default().context("artifacts required — run `make artifacts`")?,
    );
    let mut sc = DeployScenario::paper_docker();
    sc.rounds = rounds;

    let names: Vec<String> = if strategies.is_empty() {
        DEFAULT_STRATEGIES.iter().map(|s| s.to_string()).collect()
    } else {
        strategies.to_vec()
    };
    let recorder: Box<dyn Recorder> = match &opts.metrics_path {
        Some(path) => Box::new(CsvRecorder::create(path)?),
        None => Box::new(NoopRecorder::new()),
    };
    let cfg = ServiceConfig { threads: opts.threads, round_limit: None };
    let mut svc =
        CoordinatorService::new(cfg, opts.store.clone(), recorder).with_runtime(runtime);
    for name in &names {
        for r in 0..opts.replicates {
            let session = format!("fig4-{name}-r{r}");
            let mut spec = SessionSpec::live(&session, name, rounds, sc.clone(), time_scale);
            spec.seed = Some(replicate_seed(sc.seed, r));
            spec.dynamics = opts.dynamics.clone();
            svc.submit(spec)?;
        }
    }
    crate::log_info!(
        "fig4",
        "serving {} live sessions ({} strategies x {} replicates, {} rounds each)",
        names.len() * opts.replicates,
        names.len(),
        opts.replicates,
        rounds
    );
    let outcomes = svc.drain()?;
    for out in &outcomes {
        if out.phase != Phase::Finished {
            return Err(anyhow!("session {} stopped in phase {}", out.name, out.phase));
        }
    }

    // Replicate 0 of each strategy reproduces the classic Fig-4 rows
    // (seed-compatible: replicate_seed(s, 0) == s). Outcomes arrive in
    // submission order — strategy-major, replicate-minor.
    let rep = opts.replicates;
    let mut firsts = Vec::with_capacity(names.len());
    for (i, name) in names.iter().enumerate() {
        let first = &outcomes[i * rep];
        firsts.push(StrategyOutcome {
            name: name.clone(),
            recorder: recorder_from_trace(name, &first.trace),
        });
    }
    report_fig4(&firsts, out_dir)?;
    if rep > 1 {
        println!("\n=== replicated live totals ({rep} independent sessions per strategy) ===");
        println!("{:<14} {:>4} {:>16} {:>12}", "strategy", "n", "total mean (s)", "+-95% CI");
        for (i, name) in names.iter().enumerate() {
            let totals: Vec<f64> = outcomes[i * rep..(i + 1) * rep]
                .iter()
                .map(|o| o.trace.iter().map(|t| t.delay_s).sum())
                .collect();
            let ci = mean_ci(&totals);
            println!("{:<14} {:>4} {:>16.2} {:>12.2}", name, ci.n, ci.mean, ci.half_width);
        }
    }
    Ok(())
}

/// Rebuild a [`RoundRecorder`] from a persisted session trace so the
/// service path feeds the exact same Fig-4 reporting as the direct
/// [`run_strategy`] path.
fn recorder_from_trace(strategy: &str, trace: &[crate::service::TraceRow]) -> RoundRecorder {
    let mut rec = RoundRecorder::new();
    for row in trace {
        rec.push(RoundRecord {
            round: row.round,
            strategy: strategy.to_string(),
            delay: Duration::from_secs_f64(row.delay_s),
            loss: row.loss,
            placement: row.placement.clone(),
        });
    }
    rec
}

/// Render + persist the comparison (also used by the bench).
pub fn report_fig4(outcomes: &[StrategyOutcome], out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let rounds = outcomes.iter().map(|o| o.recorder.len()).max().unwrap_or(0);

    // CSV: round, <strategy> delay columns, <strategy> loss columns.
    let mut header = vec!["round".to_string()];
    for o in outcomes {
        header.push(format!("{}_delay_s", o.name));
    }
    for o in outcomes {
        header.push(format!("{}_loss", o.name));
    }
    let path = out_dir.join("fig4.csv");
    let mut w = CsvWriter::create(&path, &header)?;
    for r in 0..rounds {
        let mut row = vec![r as f64];
        for o in outcomes {
            row.push(o.recorder.records().get(r).map_or(f64::NAN, |x| x.delay.as_secs_f64()));
        }
        for o in outcomes {
            row.push(o.recorder.records().get(r).map_or(f64::NAN, |x| x.loss));
        }
        w.write_f64_row(&row)?;
    }
    w.flush()?;

    // ASCII per-round delay plot (the Fig-4 left panel).
    let series: Vec<(&str, char, Vec<f64>)> = outcomes
        .iter()
        .map(|o| {
            let glyph = match o.name.as_str() {
                "random" => 'r',
                "uniform" | "round-robin" => 'u',
                "ga" => 'g',
                "sa" => 's',
                "tabu" => 't',
                "adaptive-pso" | "pso-adaptive" => 'a',
                _ => 'p',
            };
            (o.name.as_str(), glyph, o.recorder.delays_secs())
        })
        .collect();
    let series_refs: Vec<(&str, char, &[f64])> = series
        .iter()
        .map(|(n, g, v)| (*n, *g, v.as_slice()))
        .collect();
    println!(
        "{}",
        ascii_plot("per-round processing delay (s)", &series_refs, 72, 16)
    );

    // Summary rows (the paper's headline numbers).
    println!("=== Fig-4 summary ===");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>14}",
        "strategy", "total (s)", "mean (s)", "p50 (s)", "converged@round"
    );
    let mut totals = std::collections::BTreeMap::new();
    for o in outcomes {
        let delays = o.recorder.delays_secs();
        let total: f64 = delays.iter().sum();
        totals.insert(o.name.as_str(), total);
        let summary = crate::metrics::Summary::from(&delays);
        let conv = o
            .recorder
            .convergence_round()
            .map(|r| r.to_string())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<14} {:>12.2} {:>12.3} {:>12.3} {:>14}",
            o.name, total, summary.mean, summary.p50, conv
        );
    }
    if let (Some(&pso), Some(&rand), Some(&uni)) =
        (totals.get("pso"), totals.get("random"), totals.get("uniform"))
    {
        println!(
            "\nPSO total processing time: {:.1}% faster than random, {:.1}% faster than uniform",
            (1.0 - pso / rand) * 100.0,
            (1.0 - pso / uni) * 100.0
        );
        println!("(paper reports ≈43% vs random, ≈32% vs uniform on its docker testbed)");
    }
    println!("per-round CSV: {}", path.display());
    Ok(())
}

/// End-to-end driver: PSO-placed federated training of the 1.8 M-param
/// MLP, logging delay + loss every round (EXPERIMENTS.md §E2E).
pub fn run_e2e(rounds: usize) -> Result<()> {
    let runtime = Arc::new(
        ModelRuntime::load_default().context("artifacts required — run `make artifacts`")?,
    );
    let mut sc = DeployScenario::paper_docker();
    sc.rounds = rounds;
    let outcome = run_strategy(&sc, "pso", runtime.clone(), 1.0)?;

    let losses: Vec<f64> = outcome.recorder.records().iter().map(|r| r.loss).collect();
    let delays = outcome.recorder.delays_secs();
    println!(
        "{}",
        ascii_plot(
            "global-model eval loss vs round",
            &[("loss", '*', &losses)],
            72,
            14
        )
    );
    println!(
        "{}",
        ascii_plot(
            "round processing delay (s) [pso]",
            &[("delay", 'p', &delays)],
            72,
            12
        )
    );
    let conv = outcome
        .recorder
        .convergence_round()
        .map(|r| r.to_string())
        .unwrap_or_else(|| "-".into());
    println!(
        "e2e: {} rounds, total {:.1}s, mean {:.3}s/round, placement converged @ round {}, final loss {:.4}",
        rounds,
        delays.iter().sum::<f64>(),
        outcome.recorder.mean_delay_secs(),
        conv,
        losses.last().copied().unwrap_or(f64::NAN),
    );
    Ok(())
}
