//! Fig-4 harness: the docker-analogue deployment comparison (random vs
//! uniform round-robin vs PSO, plus any other registered strategy) and
//! the end-to-end training driver. Shared by `repro compare` /
//! `repro e2e`, the examples and the `fig4_deploy` bench so every entry
//! point reports identical rows. Strategies are built through
//! [`registry`], so `--strategies ga,sa,tabu` works everywhere.

use super::ascii_plot;
use crate::configio::DeployScenario;
use crate::exp::TrialScheduler;
use crate::fl::Deployment;
use crate::metrics::{CsvWriter, RoundRecorder};
use crate::placement::registry;
use crate::runtime::ModelRuntime;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// The paper's Fig-4 strategy line-up (seed-compatible labels: the
/// round-robin baseline keeps its paper name "uniform").
pub const DEFAULT_STRATEGIES: [&str; 3] = ["random", "uniform", "pso"];

/// Outcome of one strategy's deployment run.
pub struct StrategyOutcome {
    /// The requested strategy name (alias preserved for CSV headers).
    pub name: String,
    pub recorder: RoundRecorder,
}

/// Run one strategy (any [`registry`] name or alias) through a full
/// deployment.
pub fn run_strategy(
    sc: &DeployScenario,
    name: &str,
    runtime: Arc<ModelRuntime>,
    time_scale: f64,
) -> Result<StrategyOutcome> {
    let optimizer =
        registry::build_live(name, sc.dimensions(), sc.clients.len(), sc.pso, sc.seed ^ 0xABCD)
            .map_err(|e| anyhow!(e))?;
    let session = format!("fig4-{name}");
    let mut dep = Deployment::launch(sc, &session, runtime, optimizer, time_scale)?;
    dep.run(sc.rounds)?;
    let recorder = dep.coordinator.recorder().clone();
    dep.shutdown();
    Ok(StrategyOutcome { name: name.to_string(), recorder })
}

/// The full Fig-4 comparison over `strategies` (registry names; empty ⇒
/// the paper's default trio). Writes `results/fig4.csv` (per-round
/// delays per strategy) and prints the paper-style summary (totals,
/// convergence round, percentage improvements).
pub fn run_fig4_comparison(
    rounds: usize,
    time_scale: f64,
    out_dir: &Path,
    strategies: &[String],
) -> Result<()> {
    let runtime = Arc::new(
        ModelRuntime::load_default().context("artifacts required — run `make artifacts`")?,
    );
    let mut sc = DeployScenario::paper_docker();
    sc.rounds = rounds;

    let names: Vec<String> = if strategies.is_empty() {
        DEFAULT_STRATEGIES.iter().map(|s| s.to_string()).collect()
    } else {
        strategies.to_vec()
    };
    // Each strategy's deployment is one trial on the experiment
    // scheduler. Live sessions share one broker/runtime and measure
    // real (emulated-clock) rounds, so the pool is pinned to a single
    // worker and strategies are dispatched one batch at a time — the
    // same scheduling surface as the sim tier, but a failed deployment
    // still aborts the comparison before the next strategy pays for a
    // full testbed run. Each trial is one replicate (a live round
    // cannot be re-seeded).
    let sched = TrialScheduler::new(1);
    let mut outcomes = Vec::with_capacity(names.len());
    for name in &names {
        crate::log_info!("fig4", "running strategy {name} for {rounds} rounds");
        let mut batch = sched.run(1, |_| run_strategy(&sc, name, runtime.clone(), time_scale));
        outcomes.push(batch.pop().expect("one trial per strategy")?);
    }
    report_fig4(&outcomes, out_dir)?;
    Ok(())
}

/// Render + persist the comparison (also used by the bench).
pub fn report_fig4(outcomes: &[StrategyOutcome], out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let rounds = outcomes.iter().map(|o| o.recorder.len()).max().unwrap_or(0);

    // CSV: round, <strategy> delay columns, <strategy> loss columns.
    let mut header = vec!["round".to_string()];
    for o in outcomes {
        header.push(format!("{}_delay_s", o.name));
    }
    for o in outcomes {
        header.push(format!("{}_loss", o.name));
    }
    let path = out_dir.join("fig4.csv");
    let mut w = CsvWriter::create(&path, &header)?;
    for r in 0..rounds {
        let mut row = vec![r as f64];
        for o in outcomes {
            row.push(o.recorder.records().get(r).map_or(f64::NAN, |x| x.delay.as_secs_f64()));
        }
        for o in outcomes {
            row.push(o.recorder.records().get(r).map_or(f64::NAN, |x| x.loss));
        }
        w.write_f64_row(&row)?;
    }
    w.flush()?;

    // ASCII per-round delay plot (the Fig-4 left panel).
    let series: Vec<(&str, char, Vec<f64>)> = outcomes
        .iter()
        .map(|o| {
            let glyph = match o.name.as_str() {
                "random" => 'r',
                "uniform" | "round-robin" => 'u',
                "ga" => 'g',
                "sa" => 's',
                "tabu" => 't',
                "adaptive-pso" | "pso-adaptive" => 'a',
                _ => 'p',
            };
            (o.name.as_str(), glyph, o.recorder.delays_secs())
        })
        .collect();
    let series_refs: Vec<(&str, char, &[f64])> = series
        .iter()
        .map(|(n, g, v)| (*n, *g, v.as_slice()))
        .collect();
    println!(
        "{}",
        ascii_plot("per-round processing delay (s)", &series_refs, 72, 16)
    );

    // Summary rows (the paper's headline numbers).
    println!("=== Fig-4 summary ===");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>14}",
        "strategy", "total (s)", "mean (s)", "p50 (s)", "converged@round"
    );
    let mut totals = std::collections::BTreeMap::new();
    for o in outcomes {
        let delays = o.recorder.delays_secs();
        let total: f64 = delays.iter().sum();
        totals.insert(o.name.as_str(), total);
        let summary = crate::metrics::Summary::from(&delays);
        let conv = o
            .recorder
            .convergence_round()
            .map(|r| r.to_string())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<14} {:>12.2} {:>12.3} {:>12.3} {:>14}",
            o.name, total, summary.mean, summary.p50, conv
        );
    }
    if let (Some(&pso), Some(&rand), Some(&uni)) =
        (totals.get("pso"), totals.get("random"), totals.get("uniform"))
    {
        println!(
            "\nPSO total processing time: {:.1}% faster than random, {:.1}% faster than uniform",
            (1.0 - pso / rand) * 100.0,
            (1.0 - pso / uni) * 100.0
        );
        println!("(paper reports ≈43% vs random, ≈32% vs uniform on its docker testbed)");
    }
    println!("per-round CSV: {}", path.display());
    Ok(())
}

/// End-to-end driver: PSO-placed federated training of the 1.8 M-param
/// MLP, logging delay + loss every round (EXPERIMENTS.md §E2E).
pub fn run_e2e(rounds: usize) -> Result<()> {
    let runtime = Arc::new(
        ModelRuntime::load_default().context("artifacts required — run `make artifacts`")?,
    );
    let mut sc = DeployScenario::paper_docker();
    sc.rounds = rounds;
    let outcome = run_strategy(&sc, "pso", runtime.clone(), 1.0)?;

    let losses: Vec<f64> = outcome.recorder.records().iter().map(|r| r.loss).collect();
    let delays = outcome.recorder.delays_secs();
    println!(
        "{}",
        ascii_plot(
            "global-model eval loss vs round",
            &[("loss", '*', &losses)],
            72,
            14
        )
    );
    println!(
        "{}",
        ascii_plot(
            "round processing delay (s) [pso]",
            &[("delay", 'p', &delays)],
            72,
            12
        )
    );
    let conv = outcome
        .recorder
        .convergence_round()
        .map(|r| r.to_string())
        .unwrap_or_else(|| "-".into());
    println!(
        "e2e: {} rounds, total {:.1}s, mean {:.3}s/round, placement converged @ round {}, final loss {:.4}",
        rounds,
        delays.iter().sum::<f64>(),
        outcome.recorder.mean_delay_secs(),
        conv,
        losses.last().copied().unwrap_or(f64::NAN),
    );
    Ok(())
}
