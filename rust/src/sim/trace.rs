//! Per-iteration traces of a simulation run — the raw series behind the
//! paper's Fig. 3 curves (grey per-particle, red worst, orange mean,
//! green best) plus CSV export.

use crate::metrics::CsvWriter;
use crate::pso::IterationStats;
use std::path::Path;

/// Column-oriented trace: `per_particle[p][it]`, `worst/mean/best[it]`.
#[derive(Debug, Clone, PartialEq)]
pub struct SimTrace {
    pub per_particle: Vec<Vec<f64>>,
    pub worst: Vec<f64>,
    pub mean: Vec<f64>,
    pub best: Vec<f64>,
    pub gbest: Vec<f64>,
}

impl SimTrace {
    /// Transpose the swarm's per-iteration stats into plottable series.
    pub fn from_stats(stats: &[IterationStats]) -> SimTrace {
        let particles = stats.first().map_or(0, |s| s.per_particle_tpd.len());
        let mut per_particle = vec![Vec::with_capacity(stats.len()); particles];
        let mut worst = Vec::with_capacity(stats.len());
        let mut mean = Vec::with_capacity(stats.len());
        let mut best = Vec::with_capacity(stats.len());
        let mut gbest = Vec::with_capacity(stats.len());
        for st in stats {
            for (p, &t) in st.per_particle_tpd.iter().enumerate() {
                per_particle[p].push(t);
            }
            worst.push(st.worst);
            mean.push(st.mean);
            best.push(st.best);
            gbest.push(st.gbest_tpd);
        }
        SimTrace {
            per_particle,
            worst,
            mean,
            best,
            gbest,
        }
    }

    pub fn iterations(&self) -> usize {
        self.worst.len()
    }

    /// Normalize all series by the first iteration's worst TPD (the
    /// paper plots normalized TPD).
    pub fn normalized(&self) -> SimTrace {
        let denom = self.worst.first().copied().unwrap_or(1.0).max(1e-12);
        let norm = |v: &[f64]| v.iter().map(|x| x / denom).collect::<Vec<_>>();
        SimTrace {
            per_particle: self.per_particle.iter().map(|p| norm(p)).collect(),
            worst: norm(&self.worst),
            mean: norm(&self.mean),
            best: norm(&self.best),
            gbest: norm(&self.gbest),
        }
    }

    /// Write `iteration,worst,mean,best,gbest,p0..pN` rows.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut header: Vec<String> = vec![
            "iteration".into(),
            "worst".into(),
            "mean".into(),
            "best".into(),
            "gbest".into(),
        ];
        for p in 0..self.per_particle.len() {
            header.push(format!("p{p}"));
        }
        let mut w = CsvWriter::create(path, &header)?;
        for it in 0..self.iterations() {
            let mut row = vec![
                it as f64,
                self.worst[it],
                self.mean[it],
                self.best[it],
                self.gbest[it],
            ];
            for p in &self.per_particle {
                row.push(p[it]);
            }
            w.write_f64_row(&row)?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats() -> Vec<IterationStats> {
        (0..4)
            .map(|i| {
                let ts = vec![10.0 - i as f64, 12.0 - i as f64];
                IterationStats {
                    worst: ts[1],
                    mean: (ts[0] + ts[1]) / 2.0,
                    best: ts[0],
                    gbest_tpd: ts[0],
                    per_particle_tpd: ts,
                }
            })
            .collect()
    }

    #[test]
    fn transpose_is_correct() {
        let t = SimTrace::from_stats(&fake_stats());
        assert_eq!(t.iterations(), 4);
        assert_eq!(t.per_particle.len(), 2);
        assert_eq!(t.per_particle[0], vec![10.0, 9.0, 8.0, 7.0]);
        assert_eq!(t.worst, vec![12.0, 11.0, 10.0, 9.0]);
    }

    #[test]
    fn normalized_starts_at_one() {
        let t = SimTrace::from_stats(&fake_stats()).normalized();
        assert!((t.worst[0] - 1.0).abs() < 1e-12);
        assert!(t.best.iter().all(|&x| x <= 1.0));
    }

    #[test]
    fn csv_roundtrip_row_count() {
        let t = SimTrace::from_stats(&fake_stats());
        let path = std::env::temp_dir().join("repro_trace_test.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 5); // header + 4 iterations
        assert!(text.starts_with("iteration,worst,mean,best,gbest,p0,p1"));
    }
}
