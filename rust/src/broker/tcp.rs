//! TCP transport for the broker — the cross-process face of the edge
//! broker (the paper's deployment runs an MQTT broker as an edge
//! service; this is our equivalent for multi-process runs).
//!
//! Wire protocol (all integers big-endian):
//!
//! ```text
//! frame   := u32 length, then `length` bytes of body
//! body    := opcode u8, topic_len u16, topic bytes, payload bytes
//! opcode  := 1 SUB | 2 UNSUB | 3 PUB | 4 PUB_RETAIN
//! ```
//!
//! Inbound PUB frames are injected into the in-process [`Broker`];
//! subscriptions attach a forwarder that frames matched messages back to
//! the socket. QoS 0, no acks.

use super::{Broker, Message};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

const OP_SUB: u8 = 1;
const OP_UNSUB: u8 = 2;
const OP_PUB: u8 = 3;
const OP_PUB_RETAIN: u8 = 4;

/// Hard cap on frame size (a JSON-coded 1.8 M-param model is ~30 MB;
/// leave generous headroom).
const MAX_FRAME: u32 = 256 * 1024 * 1024;

fn write_frame(w: &mut impl Write, opcode: u8, topic: &str, payload: &[u8]) -> std::io::Result<()> {
    let body_len = 1 + 2 + topic.len() + payload.len();
    w.write_all(&(body_len as u32).to_be_bytes())?;
    w.write_all(&[opcode])?;
    w.write_all(&(topic.len() as u16).to_be_bytes())?;
    w.write_all(topic.as_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

fn read_frame(r: &mut impl Read) -> std::io::Result<(u8, String, Vec<u8>)> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_be_bytes(len4);
    if len < 3 || len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    let opcode = body[0];
    let tlen = u16::from_be_bytes([body[1], body[2]]) as usize;
    if 3 + tlen > body.len() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "topic length exceeds frame",
        ));
    }
    let topic = String::from_utf8(body[3..3 + tlen].to_vec())
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let payload = body[3 + tlen..].to_vec();
    Ok((opcode, topic, payload))
}

/// TCP front-end over an in-process [`Broker`].
pub struct TcpBrokerServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpBrokerServer {
    /// Bind and start accepting (`addr` like "127.0.0.1:0").
    pub fn start(addr: &str, broker: Broker) -> std::io::Result<TcpBrokerServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let broker = broker.clone();
                        let stop3 = stop2.clone();
                        std::thread::spawn(move || serve_connection(stream, broker, stop3));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(TcpBrokerServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// Bound address (use with port 0 for tests).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for TcpBrokerServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_connection(stream: TcpStream, broker: Broker, stop: Arc<AtomicBool>) {
    // One broker client id per connection; its queue is drained by the
    // forwarder thread below, subscriptions are managed by the reader.
    let id = broker.alloc_id();
    let (tx, rx) = std::sync::mpsc::channel::<Message>();
    let writer = Arc::new(Mutex::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    }));

    // Forwarder: in-proc queue → socket frames.
    let stop_fwd = stop.clone();
    let writer2 = writer.clone();
    let forward = std::thread::spawn(move || loop {
        if stop_fwd.load(Ordering::Relaxed) {
            break;
        }
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(msg) => {
                let mut w = writer2.lock().unwrap();
                if write_frame(&mut *w, OP_PUB, &msg.topic, &msg.payload).is_err() {
                    break;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
            Err(_) => break,
        }
    });

    // Reader: socket frames → broker calls.
    let mut reader = stream;
    let _ = reader.set_read_timeout(Some(Duration::from_millis(100)));
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match read_frame(&mut reader) {
            Ok((OP_SUB, filter, _)) => {
                let _ = broker.subscribe(id, &filter, tx.clone());
            }
            Ok((OP_UNSUB, filter, _)) => {
                broker.unsubscribe(id, &filter);
            }
            Ok((OP_PUB, topic, payload)) => {
                let _ = broker.publish(Message::new(topic, payload));
            }
            Ok((OP_PUB_RETAIN, topic, payload)) => {
                let _ = broker.publish(Message::new(topic, payload).retained());
            }
            Ok(_) => break, // unknown opcode: drop connection
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        }
    }
    broker.disconnect(id);
    drop(tx);
    let _ = forward.join();
    let _ = reader.shutdown(Shutdown::Both);
}

/// Client side of the TCP transport.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    /// Connect to a [`TcpBrokerServer`].
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<TcpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(TcpClient { stream })
    }

    /// Subscribe to a filter.
    pub fn subscribe(&mut self, filter: &str) -> std::io::Result<()> {
        write_frame(&mut self.stream, OP_SUB, filter, &[])
    }

    /// Remove a subscription.
    pub fn unsubscribe(&mut self, filter: &str) -> std::io::Result<()> {
        write_frame(&mut self.stream, OP_UNSUB, filter, &[])
    }

    /// Publish bytes to a topic.
    pub fn publish(&mut self, topic: &str, payload: &[u8]) -> std::io::Result<()> {
        write_frame(&mut self.stream, OP_PUB, topic, payload)
    }

    /// Publish with retention.
    pub fn publish_retained(&mut self, topic: &str, payload: &[u8]) -> std::io::Result<()> {
        write_frame(&mut self.stream, OP_PUB_RETAIN, topic, payload)
    }

    /// Blocking receive of the next message frame.
    pub fn recv(&mut self, timeout: Duration) -> std::io::Result<Message> {
        self.stream.set_read_timeout(Some(timeout))?;
        let (op, topic, payload) = read_frame(&mut self.stream)?;
        if op != OP_PUB {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("unexpected opcode {op}"),
            ));
        }
        Ok(Message::new(topic, payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_PUB, "a/b", b"payload").unwrap();
        let (op, topic, payload) = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(op, OP_PUB);
        assert_eq!(topic, "a/b");
        assert_eq!(payload, b"payload");
    }

    #[test]
    fn frame_rejects_bad_lengths() {
        // Declared length too small.
        let buf = 2u32.to_be_bytes().to_vec();
        assert!(read_frame(&mut &buf[..]).is_err());
        // Topic length exceeding body.
        let mut buf = Vec::new();
        buf.extend_from_slice(&5u32.to_be_bytes());
        buf.push(OP_PUB);
        buf.extend_from_slice(&100u16.to_be_bytes());
        buf.extend_from_slice(b"ab");
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn empty_payload_frame() {
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_SUB, "fl/#", &[]).unwrap();
        let (op, topic, payload) = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(op, OP_SUB);
        assert_eq!(topic, "fl/#");
        assert!(payload.is_empty());
    }
}
