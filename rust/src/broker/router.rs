//! Subscription table + retained store: the broker's routing core.

use super::{topic_matches, Message};
use std::collections::HashMap;
use std::sync::mpsc::Sender;

/// A subscriber endpoint: id + queue sender.
struct Subscription {
    client: u64,
    filter: String,
    tx: Sender<Message>,
}

/// Topic router. Not thread-safe by itself — [`super::Broker`] wraps it
/// in a mutex (routing is cheap; payload delivery is just an Arc clone).
#[derive(Default)]
pub struct Router {
    subs: Vec<Subscription>,
    retained: HashMap<String, Message>,
    delivered: u64,
    dropped: u64,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Add a subscription; replays the retained message(s) matching the
    /// filter (MQTT retained semantics).
    pub fn subscribe(&mut self, client: u64, filter: &str, tx: Sender<Message>) {
        for (topic, msg) in &self.retained {
            if topic_matches(filter, topic) {
                let _ = tx.send(msg.clone());
            }
        }
        self.subs.push(Subscription {
            client,
            filter: filter.to_string(),
            tx,
        });
    }

    /// Remove one subscription (client + exact filter).
    pub fn unsubscribe(&mut self, client: u64, filter: &str) {
        self.subs
            .retain(|s| !(s.client == client && s.filter == filter));
    }

    /// Remove all subscriptions of a client (disconnect).
    pub fn disconnect(&mut self, client: u64) {
        self.subs.retain(|s| s.client != client);
    }

    /// Deliver `msg` to every matching subscriber; store if retained.
    /// MQTT semantics: a retained publish with an EMPTY payload clears
    /// the retained message for that topic (and is not delivered).
    /// Returns the number of deliveries.
    pub fn publish(&mut self, msg: &Message) -> usize {
        if msg.retain {
            if msg.payload.is_empty() {
                self.retained.remove(&msg.topic);
                return 0;
            }
            self.retained.insert(msg.topic.clone(), msg.clone());
        }
        let mut delivered = 0;
        for s in &self.subs {
            if topic_matches(&s.filter, &msg.topic) {
                if s.tx.send(msg.clone()).is_ok() {
                    delivered += 1;
                } else {
                    self.dropped += 1;
                }
            }
        }
        self.delivered += delivered as u64;
        delivered
    }

    /// (delivered, dropped) counters for metrics.
    pub fn stats(&self) -> (u64, u64) {
        (self.delivered, self.dropped)
    }

    /// Number of active subscriptions.
    pub fn subscription_count(&self) -> usize {
        self.subs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn routes_to_matching_subscribers() {
        let mut r = Router::new();
        let (tx1, rx1) = channel();
        let (tx2, rx2) = channel();
        r.subscribe(1, "a/+", tx1);
        r.subscribe(2, "a/b", tx2);
        let n = r.publish(&Message::new("a/b", b"hi".to_vec()));
        assert_eq!(n, 2);
        assert_eq!(rx1.try_recv().unwrap().topic, "a/b");
        assert_eq!(rx2.try_recv().unwrap().topic, "a/b");
        let n = r.publish(&Message::new("a/c", b"yo".to_vec()));
        assert_eq!(n, 1);
        assert!(rx2.try_recv().is_err());
        assert_eq!(rx1.try_recv().unwrap().topic, "a/c");
    }

    #[test]
    fn retained_replayed_on_subscribe() {
        let mut r = Router::new();
        r.publish(&Message::new("cfg/x", b"1".to_vec()).retained());
        let (tx, rx) = channel();
        r.subscribe(1, "cfg/#", tx);
        assert_eq!(&**rx.try_recv().unwrap().payload, b"1");
    }

    #[test]
    fn retained_cleared_by_empty_payload() {
        let mut r = Router::new();
        r.publish(&Message::new("cfg/x", b"1".to_vec()).retained());
        r.publish(&Message::new("cfg/x", Vec::new()).retained());
        let (tx, rx) = channel();
        r.subscribe(1, "cfg/x", tx);
        assert!(rx.try_recv().is_err(), "cleared retained must not replay");
    }

    #[test]
    fn retained_overwritten() {
        let mut r = Router::new();
        r.publish(&Message::new("cfg/x", b"1".to_vec()).retained());
        r.publish(&Message::new("cfg/x", b"2".to_vec()).retained());
        let (tx, rx) = channel();
        r.subscribe(1, "cfg/x", tx);
        assert_eq!(&**rx.try_recv().unwrap().payload, b"2");
    }

    #[test]
    fn unsubscribe_and_disconnect() {
        let mut r = Router::new();
        let (tx, rx) = channel();
        r.subscribe(1, "a", tx.clone());
        r.subscribe(1, "b", tx);
        r.unsubscribe(1, "a");
        assert_eq!(r.publish(&Message::new("a", vec![])), 0);
        assert_eq!(r.publish(&Message::new("b", vec![])), 1);
        rx.try_recv().unwrap();
        r.disconnect(1);
        assert_eq!(r.publish(&Message::new("b", vec![])), 0);
        assert_eq!(r.subscription_count(), 0);
    }

    #[test]
    fn dead_receiver_counts_dropped() {
        let mut r = Router::new();
        let (tx, rx) = channel();
        r.subscribe(1, "a", tx);
        drop(rx);
        assert_eq!(r.publish(&Message::new("a", vec![])), 0);
        assert_eq!(r.stats().1, 1);
    }
}
