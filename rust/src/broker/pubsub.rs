//! Transport abstraction: the FL agents speak to *a* pub/sub endpoint —
//! in-process ([`BrokerClient`]) for single-process deployments and
//! benches, TCP ([`TcpPubSub`]) for real multi-process runs where each
//! client is its own OS process attached to the edge broker.

use super::{BrokerClient, Message, TcpClient};
use std::time::Duration;

/// What an FL agent needs from its messaging layer.
pub trait PubSub: Send {
    fn subscribe(&mut self, filter: &str) -> Result<(), String>;
    fn unsubscribe(&mut self, filter: &str) -> Result<(), String>;
    fn publish(&mut self, topic: &str, payload: Vec<u8>) -> Result<(), String>;
    /// Publish with MQTT retained semantics (used by the join barrier so
    /// a late-starting coordinator still sees earlier workers).
    fn publish_retained(&mut self, topic: &str, payload: Vec<u8>) -> Result<(), String>;
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Message, String>;
}

impl PubSub for BrokerClient {
    fn subscribe(&mut self, filter: &str) -> Result<(), String> {
        BrokerClient::subscribe(self, filter)
    }

    fn unsubscribe(&mut self, filter: &str) -> Result<(), String> {
        BrokerClient::unsubscribe(self, filter);
        Ok(())
    }

    fn publish(&mut self, topic: &str, payload: Vec<u8>) -> Result<(), String> {
        BrokerClient::publish(self, topic, payload).map(|_| ())
    }

    fn publish_retained(&mut self, topic: &str, payload: Vec<u8>) -> Result<(), String> {
        BrokerClient::publish_retained(self, topic, payload).map(|_| ())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Message, String> {
        BrokerClient::recv_timeout(self, timeout)
    }
}

/// TCP-backed pub/sub endpoint (wraps [`TcpClient`]).
pub struct TcpPubSub {
    client: TcpClient,
}

impl TcpPubSub {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<TcpPubSub> {
        Ok(TcpPubSub {
            client: TcpClient::connect(addr)?,
        })
    }
}

impl PubSub for TcpPubSub {
    fn subscribe(&mut self, filter: &str) -> Result<(), String> {
        self.client.subscribe(filter).map_err(|e| e.to_string())
    }

    fn unsubscribe(&mut self, filter: &str) -> Result<(), String> {
        self.client.unsubscribe(filter).map_err(|e| e.to_string())
    }

    fn publish(&mut self, topic: &str, payload: Vec<u8>) -> Result<(), String> {
        self.client
            .publish(topic, &payload)
            .map_err(|e| e.to_string())
    }

    fn publish_retained(&mut self, topic: &str, payload: Vec<u8>) -> Result<(), String> {
        self.client
            .publish_retained(topic, &payload)
            .map_err(|e| e.to_string())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Message, String> {
        self.client.recv(timeout).map_err(|e| {
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut
            {
                "tcp: recv timeout".to_string()
            } else {
                format!("tcp: {e}")
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Broker, TcpBrokerServer};
    use super::*;

    #[test]
    fn both_transports_satisfy_the_trait() {
        fn roundtrip<C: PubSub>(mut c: C, settle: Duration) {
            c.subscribe("trait/t").unwrap();
            std::thread::sleep(settle);
            c.publish("trait/t", b"x".to_vec()).unwrap();
            let m = c.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(&**m.payload, b"x");
            c.unsubscribe("trait/t").unwrap();
        }
        let broker = Broker::new();
        roundtrip(broker.connect("inproc"), Duration::ZERO);

        let server = TcpBrokerServer::start("127.0.0.1:0", broker).unwrap();
        roundtrip(
            TcpPubSub::connect(&server.addr()).unwrap(),
            Duration::from_millis(100),
        );
    }
}
