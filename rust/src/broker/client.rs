//! In-process broker client handle: subscribe / publish / receive.

use super::{Broker, Message};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// One connected client. Receiving is single-consumer (`&mut self`);
/// publishing is `&self` and may happen from the same thread that
/// receives.
pub struct BrokerClient {
    broker: Broker,
    id: u64,
    name: String,
    tx: Sender<Message>,
    rx: Receiver<Message>,
    subscriptions: Vec<String>,
}

impl BrokerClient {
    pub(super) fn new(
        broker: Broker,
        id: u64,
        name: String,
        tx: Sender<Message>,
        rx: Receiver<Message>,
    ) -> BrokerClient {
        BrokerClient {
            broker,
            id,
            name,
            tx,
            rx,
            subscriptions: Vec::new(),
        }
    }

    /// Client id assigned by the broker.
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Subscribe to a filter (retained messages are replayed immediately
    /// into the receive queue).
    pub fn subscribe(&mut self, filter: &str) -> Result<(), String> {
        self.broker.subscribe(self.id, filter, self.tx.clone())?;
        self.subscriptions.push(filter.to_string());
        Ok(())
    }

    /// Remove one subscription.
    pub fn unsubscribe(&mut self, filter: &str) {
        self.broker.unsubscribe(self.id, filter);
        self.subscriptions.retain(|f| f != filter);
    }

    /// Publish owned bytes.
    pub fn publish(&self, topic: impl Into<String>, payload: Vec<u8>) -> Result<usize, String> {
        self.broker.publish(Message::new(topic, payload))
    }

    /// Publish an `Arc` payload (zero-copy fan-out).
    pub fn publish_shared(
        &self,
        topic: impl Into<String>,
        payload: Arc<Vec<u8>>,
    ) -> Result<usize, String> {
        self.broker.publish(Message::shared(topic, payload))
    }

    /// Publish with retention.
    pub fn publish_retained(
        &self,
        topic: impl Into<String>,
        payload: Vec<u8>,
    ) -> Result<usize, String> {
        self.broker.publish(Message::new(topic, payload).retained())
    }

    /// Publish an `Arc` payload with retention (zero-copy fan-out AND
    /// late-subscriber replay — the global-model broadcast path).
    pub fn publish_shared_retained(
        &self,
        topic: impl Into<String>,
        payload: Arc<Vec<u8>>,
    ) -> Result<usize, String> {
        self.broker.publish(Message::shared(topic, payload).retained())
    }

    /// Clear a retained message (MQTT empty-retained semantics).
    pub fn clear_retained(&self, topic: impl Into<String>) -> Result<usize, String> {
        self.broker.publish(Message::new(topic, Vec::new()).retained())
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<Message, String> {
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => format!("client {}: recv timeout", self.name),
            RecvTimeoutError::Disconnected => format!("client {}: broker gone", self.name),
        })
    }

    /// Non-blocking receive.
    pub fn try_recv(&mut self) -> Option<Message> {
        self.rx.try_recv().ok()
    }
}

impl Drop for BrokerClient {
    fn drop(&mut self) {
        self.broker.disconnect(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::super::Broker;
    use std::time::Duration;

    #[test]
    fn try_recv_nonblocking() {
        let broker = Broker::new();
        let mut c = broker.connect("c");
        c.subscribe("t").unwrap();
        assert!(c.try_recv().is_none());
        c.publish("t", b"x".to_vec()).unwrap();
        assert!(c.try_recv().is_some());
    }

    #[test]
    fn self_publish_delivers() {
        // A client subscribed to its own topic hears itself (MQTT default).
        let broker = Broker::new();
        let mut c = broker.connect("c");
        c.subscribe("loop").unwrap();
        c.publish("loop", vec![1]).unwrap();
        assert!(c.recv_timeout(Duration::from_millis(100)).is_ok());
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let broker = Broker::new();
        let mut c = broker.connect("c");
        c.subscribe("a").unwrap();
        c.unsubscribe("a");
        c.publish("a", vec![]).unwrap();
        assert!(c.try_recv().is_none());
    }
}
