//! MQTT-lite publish/subscribe broker (substrate — paper §II).
//!
//! SDFLMQ delegates all FL coordination to topic-based pub/sub: roles are
//! topics, role candidates subscribe, and anyone may publish to a role's
//! topic. This module provides the broker that makes that work:
//!
//! * hierarchical [`topic`]s with MQTT `+`/`#` wildcard filters,
//! * retained messages (late subscribers get the last value),
//! * an in-process transport (lock-protected router + mpsc queues,
//!   `Arc`-shared payloads so a 7.5 MB model broadcast is zero-copy),
//! * a length-prefixed [`tcp`] transport for cross-process deployments
//!   (the docker-analogue of the paper's edge broker).
//!
//! QoS is 0 (at-most-once) throughout — the paper's flow needs nothing
//! stronger on a reliable transport.

mod broker_core;
mod client;
mod message;
mod pubsub;
mod router;
mod tcp;
mod topic;

pub use broker_core::{Broker, Intercept, Interceptor};
pub use client::BrokerClient;
pub use message::Message;
pub use pubsub::{PubSub, TcpPubSub};
pub use router::Router;
pub use tcp::{TcpBrokerServer, TcpClient};
pub use topic::{topic_matches, validate_filter, validate_topic};
