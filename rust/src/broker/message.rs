//! Broker message: topic + `Arc`-shared payload.
//!
//! Payloads are `Arc<Vec<u8>>` so fanning a 7.5 MB model broadcast out to
//! N subscribers clones a pointer, not the bytes (perf-critical for the
//! round loop; see EXPERIMENTS.md §Perf).

use std::sync::Arc;

/// One published message as delivered to subscribers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    pub topic: String,
    pub payload: Arc<Vec<u8>>,
    /// Whether the publisher asked for retention (late subscribers get
    /// the most recent retained message per topic on subscribe).
    pub retain: bool,
}

impl Message {
    /// Owned-payload constructor.
    pub fn new(topic: impl Into<String>, payload: Vec<u8>) -> Message {
        Message {
            topic: topic.into(),
            payload: Arc::new(payload),
            retain: false,
        }
    }

    /// Shared-payload constructor (zero-copy fan-out).
    pub fn shared(topic: impl Into<String>, payload: Arc<Vec<u8>>) -> Message {
        Message {
            topic: topic.into(),
            payload,
            retain: false,
        }
    }

    /// Mark for retention.
    pub fn retained(mut self) -> Message {
        self.retain = true;
        self
    }

    /// Payload as UTF-8 (for JSON control messages).
    pub fn text(&self) -> Result<&str, std::str::Utf8Error> {
        std::str::from_utf8(&self.payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_payload_is_zero_copy() {
        let payload = Arc::new(vec![1u8; 1024]);
        let a = Message::shared("t", payload.clone());
        let b = a.clone();
        assert!(Arc::ptr_eq(&a.payload, &b.payload));
        assert!(Arc::ptr_eq(&a.payload, &payload));
    }

    #[test]
    fn text_decodes_utf8() {
        let m = Message::new("t", b"{\"x\":1}".to_vec());
        assert_eq!(m.text().unwrap(), "{\"x\":1}");
    }

    #[test]
    fn retained_flag() {
        assert!(Message::new("t", vec![]).retained().retain);
        assert!(!Message::new("t", vec![]).retain);
    }
}
