//! MQTT topic syntax: `/`-separated levels, `+` (single-level) and `#`
//! (multi-level, final position) wildcards in filters.

/// True if `topic` is a valid *publish* topic (no wildcards, non-empty
/// levels allowed to be empty per MQTT but we forbid empty topic).
pub fn validate_topic(topic: &str) -> Result<(), String> {
    if topic.is_empty() {
        return Err("empty topic".into());
    }
    if topic.contains('+') || topic.contains('#') {
        return Err(format!("wildcard in publish topic {topic:?}"));
    }
    Ok(())
}

/// True if `filter` is a valid subscription filter.
pub fn validate_filter(filter: &str) -> Result<(), String> {
    if filter.is_empty() {
        return Err("empty filter".into());
    }
    let levels: Vec<&str> = filter.split('/').collect();
    for (i, lvl) in levels.iter().enumerate() {
        match *lvl {
            "#" => {
                if i + 1 != levels.len() {
                    return Err(format!("'#' must be final in {filter:?}"));
                }
            }
            "+" => {}
            l if l.contains('+') || l.contains('#') => {
                return Err(format!("wildcard must occupy a whole level in {filter:?}"));
            }
            _ => {}
        }
    }
    Ok(())
}

/// MQTT filter matching.
pub fn topic_matches(filter: &str, topic: &str) -> bool {
    let mut f = filter.split('/');
    let mut t = topic.split('/');
    loop {
        match (f.next(), t.next()) {
            (Some("#"), _) => return true,
            (Some("+"), Some(_)) => {}
            (Some(fl), Some(tl)) if fl == tl => {}
            (None, None) => return true,
            _ => return false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match() {
        assert!(topic_matches("a/b/c", "a/b/c"));
        assert!(!topic_matches("a/b/c", "a/b"));
        assert!(!topic_matches("a/b", "a/b/c"));
        assert!(!topic_matches("a/b/c", "a/b/d"));
    }

    #[test]
    fn plus_wildcard() {
        assert!(topic_matches("session/+/round", "session/42/round"));
        assert!(!topic_matches("session/+/round", "session/42/x/round"));
        assert!(topic_matches("+/+/+", "a/b/c"));
        assert!(!topic_matches("+", "a/b"));
    }

    #[test]
    fn hash_wildcard() {
        assert!(topic_matches("session/#", "session/42/round"));
        assert!(topic_matches("session/#", "session"));
        assert!(topic_matches("#", "anything/at/all"));
        assert!(!topic_matches("session/#", "other/42"));
    }

    #[test]
    fn validation() {
        assert!(validate_topic("session/1/slot/0").is_ok());
        assert!(validate_topic("a/+/b").is_err());
        assert!(validate_topic("").is_err());
        assert!(validate_filter("session/+/slot/#").is_ok());
        assert!(validate_filter("a/#/b").is_err());
        assert!(validate_filter("a/b+c").is_err());
        assert!(validate_filter("").is_err());
    }

    #[test]
    fn hash_matches_parent_level() {
        // MQTT-conformant: "sport/#" matches "sport".
        assert!(topic_matches("sport/#", "sport"));
    }
}
