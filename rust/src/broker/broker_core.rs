//! The in-process broker: a mutex-wrapped [`Router`] shared by
//! [`super::BrokerClient`] handles. This is the "broker at the edge" the
//! SDFLMQ deployment connects to; the [`super::TcpBrokerServer`] exposes
//! the same router over TCP for cross-process use.

use super::{validate_filter, validate_topic, BrokerClient, Message, Router};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

/// What a publish-path [`Interceptor`] decides for one message. The
/// default everywhere is [`Intercept::Deliver`]; everything else exists
/// for the fault-injection plane (`fault::BrokerFaults`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Intercept {
    /// Route normally.
    Deliver,
    /// Silently lose the message (QoS-0 loss).
    Drop,
    /// Deliver the message twice (duplicate delivery).
    Duplicate,
    /// Sleep `ms` wall milliseconds before routing (in-flight latency).
    DelayMs(u64),
    /// Hold the message back and deliver it *after* the next publish
    /// (a one-slot reorder buffer). A held message is released by the
    /// next publish regardless of that message's own verdict.
    Reorder,
}

/// Publish-path hook: inspects `(topic, payload_len)` and rules on the
/// message's fate. Interceptors must be cheap and lock-free towards the
/// broker (they run inside `publish`, before the router lock).
pub trait Interceptor: Send + Sync {
    fn intercept(&self, topic: &str, payload_len: usize) -> Intercept;
}

/// Handle to a running broker. Cheap to clone.
#[derive(Clone)]
pub struct Broker {
    inner: Arc<BrokerInner>,
}

pub(super) struct BrokerInner {
    pub(super) router: Mutex<Router>,
    next_client: AtomicU64,
    /// Optional publish-path fault hook (`None` = zero-cost passthrough).
    interceptor: Mutex<Option<Arc<dyn Interceptor>>>,
    /// The [`Intercept::Reorder`] one-slot holdback buffer.
    held: Mutex<Option<Message>>,
}

impl Default for Broker {
    fn default() -> Self {
        Self::new()
    }
}

impl Broker {
    pub fn new() -> Broker {
        Broker {
            inner: Arc::new(BrokerInner {
                router: Mutex::new(Router::new()),
                next_client: AtomicU64::new(1),
                interceptor: Mutex::new(None),
                held: Mutex::new(None),
            }),
        }
    }

    /// Install (or clear) the publish-path interceptor. Clearing also
    /// releases any reorder-held message so nothing is stranded.
    pub fn set_interceptor(&self, hook: Option<Arc<dyn Interceptor>>) {
        let clearing = hook.is_none();
        *self.inner.interceptor.lock().unwrap() = hook;
        if clearing {
            if let Some(held) = self.inner.held.lock().unwrap().take() {
                self.route(&held);
            }
        }
    }

    /// Route one message through the router and bump the obs counters.
    fn route(&self, msg: &Message) -> usize {
        let delivered = self.inner.router.lock().unwrap().publish(msg);
        if delivered > 0 {
            crate::obs::defs::BROKER_MSGS_OUT.add(delivered as u64);
            crate::obs::defs::BROKER_BYTES_OUT.add((delivered * msg.payload.len()) as u64);
        }
        delivered
    }

    /// Connect a new in-process client.
    pub fn connect(&self, name: &str) -> BrokerClient {
        let id = self.alloc_id();
        let (tx, rx) = channel();
        BrokerClient::new(self.clone(), id, name.to_string(), tx, rx)
    }

    /// Allocate a fresh client id (used by the TCP transport, which
    /// manages its subscription lifetime manually).
    pub(super) fn alloc_id(&self) -> u64 {
        self.inner.next_client.fetch_add(1, Ordering::Relaxed)
    }

    /// Publish on behalf of a client (validates the topic). When an
    /// [`Interceptor`] is installed, the message runs through it first —
    /// the fault-injection seam for the live path. Without one, this is
    /// the same single-lock route it always was.
    pub fn publish(&self, msg: Message) -> Result<usize, String> {
        validate_topic(&msg.topic)?;
        crate::obs::defs::BROKER_MSGS_IN.inc();
        crate::obs::defs::BROKER_BYTES_IN.add(msg.payload.len() as u64);
        let hook = self.inner.interceptor.lock().unwrap().clone();
        let verdict = match &hook {
            Some(h) => h.intercept(&msg.topic, msg.payload.len()),
            None => Intercept::Deliver,
        };
        // Any publish releases a reorder-held predecessor *after* the
        // current message — that swap is the reorder.
        let held = if hook.is_some() { self.inner.held.lock().unwrap().take() } else { None };
        let delivered = match verdict {
            Intercept::Drop => 0,
            Intercept::Duplicate => {
                let first = self.route(&msg);
                first + self.route(&msg)
            }
            Intercept::DelayMs(ms) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                self.route(&msg)
            }
            Intercept::Reorder if held.is_none() => {
                *self.inner.held.lock().unwrap() = Some(msg);
                return Ok(0);
            }
            Intercept::Deliver | Intercept::Reorder => self.route(&msg),
        };
        let mut total = delivered;
        if let Some(h) = held {
            total += self.route(&h);
        }
        Ok(total)
    }

    pub(super) fn subscribe(
        &self,
        client: u64,
        filter: &str,
        tx: std::sync::mpsc::Sender<Message>,
    ) -> Result<(), String> {
        validate_filter(filter)?;
        self.inner.router.lock().unwrap().subscribe(client, filter, tx);
        Ok(())
    }

    pub(super) fn unsubscribe(&self, client: u64, filter: &str) {
        self.inner.router.lock().unwrap().unsubscribe(client, filter);
    }

    pub(super) fn disconnect(&self, client: u64) {
        self.inner.router.lock().unwrap().disconnect(client);
    }

    /// (delivered, dropped) counters.
    pub fn stats(&self) -> (u64, u64) {
        self.inner.router.lock().unwrap().stats()
    }

    /// Active subscription count.
    pub fn subscription_count(&self) -> usize {
        self.inner.router.lock().unwrap().subscription_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn pubsub_roundtrip() {
        let broker = Broker::new();
        let mut sub = broker.connect("sub");
        let pub_ = broker.connect("pub");
        sub.subscribe("fl/+/model").unwrap();
        pub_.publish("fl/3/model", b"params".to_vec()).unwrap();
        let msg = sub.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(msg.topic, "fl/3/model");
        assert_eq!(&**msg.payload, b"params");
    }

    #[test]
    fn publish_to_wildcard_rejected() {
        let broker = Broker::new();
        let c = broker.connect("c");
        assert!(c.publish("a/+", vec![]).is_err());
    }

    #[test]
    fn drop_disconnects() {
        let broker = Broker::new();
        {
            let mut c = broker.connect("temp");
            c.subscribe("x").unwrap();
            assert_eq!(broker.subscription_count(), 1);
        }
        assert_eq!(broker.subscription_count(), 0);
    }

    /// Scripted interceptor: pops one verdict per publish, then delivers.
    struct Script(Mutex<Vec<Intercept>>);

    impl Interceptor for Script {
        fn intercept(&self, _topic: &str, _len: usize) -> Intercept {
            self.0.lock().unwrap().pop().unwrap_or(Intercept::Deliver)
        }
    }

    fn recv_text(sub: &mut BrokerClient) -> String {
        let msg = sub.recv_timeout(Duration::from_secs(1)).unwrap();
        String::from_utf8((*msg.payload).clone()).unwrap()
    }

    #[test]
    fn interceptor_drops_duplicates_and_reorders() {
        let broker = Broker::new();
        let mut sub = broker.connect("sub");
        let p = broker.connect("pub");
        sub.subscribe("t").unwrap();
        // Verdicts pop back-to-front: drop "a", duplicate "b",
        // reorder "c" behind "d".
        broker.set_interceptor(Some(Arc::new(Script(Mutex::new(vec![
            Intercept::Deliver,  // d (releases held c after itself)
            Intercept::Reorder,  // c
            Intercept::Duplicate, // b
            Intercept::Drop,     // a
        ])))));
        assert_eq!(p.publish("t", b"a".to_vec()).unwrap(), 0);
        assert_eq!(p.publish("t", b"b".to_vec()).unwrap(), 2);
        assert_eq!(p.publish("t", b"c".to_vec()).unwrap(), 0);
        assert_eq!(p.publish("t", b"d".to_vec()).unwrap(), 2);
        let got: Vec<String> = (0..4).map(|_| recv_text(&mut sub)).collect();
        assert_eq!(got, ["b", "b", "d", "c"]);
        // Clearing the hook restores plain delivery.
        broker.set_interceptor(None);
        assert_eq!(p.publish("t", b"e".to_vec()).unwrap(), 1);
        assert_eq!(recv_text(&mut sub), "e");
    }

    #[test]
    fn clearing_the_interceptor_releases_a_held_message() {
        let broker = Broker::new();
        let mut sub = broker.connect("sub");
        let p = broker.connect("pub");
        sub.subscribe("t").unwrap();
        broker.set_interceptor(Some(Arc::new(Script(Mutex::new(vec![Intercept::Reorder])))));
        assert_eq!(p.publish("t", b"held".to_vec()).unwrap(), 0);
        broker.set_interceptor(None);
        assert_eq!(recv_text(&mut sub), "held");
    }

    #[test]
    fn cross_thread_delivery() {
        let broker = Broker::new();
        let mut sub = broker.connect("sub");
        sub.subscribe("work/#").unwrap();
        let b2 = broker.clone();
        let t = std::thread::spawn(move || {
            let p = b2.connect("worker");
            for i in 0..100 {
                p.publish(format!("work/{i}"), vec![i as u8]).unwrap();
            }
        });
        let mut got = 0;
        while got < 100 {
            sub.recv_timeout(Duration::from_secs(2)).unwrap();
            got += 1;
        }
        t.join().unwrap();
        assert_eq!(broker.stats().0, 100);
    }
}
