//! The in-process broker: a mutex-wrapped [`Router`] shared by
//! [`super::BrokerClient`] handles. This is the "broker at the edge" the
//! SDFLMQ deployment connects to; the [`super::TcpBrokerServer`] exposes
//! the same router over TCP for cross-process use.

use super::{validate_filter, validate_topic, BrokerClient, Message, Router};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

/// Handle to a running broker. Cheap to clone.
#[derive(Clone)]
pub struct Broker {
    inner: Arc<BrokerInner>,
}

pub(super) struct BrokerInner {
    pub(super) router: Mutex<Router>,
    next_client: AtomicU64,
}

impl Default for Broker {
    fn default() -> Self {
        Self::new()
    }
}

impl Broker {
    pub fn new() -> Broker {
        Broker {
            inner: Arc::new(BrokerInner {
                router: Mutex::new(Router::new()),
                next_client: AtomicU64::new(1),
            }),
        }
    }

    /// Connect a new in-process client.
    pub fn connect(&self, name: &str) -> BrokerClient {
        let id = self.alloc_id();
        let (tx, rx) = channel();
        BrokerClient::new(self.clone(), id, name.to_string(), tx, rx)
    }

    /// Allocate a fresh client id (used by the TCP transport, which
    /// manages its subscription lifetime manually).
    pub(super) fn alloc_id(&self) -> u64 {
        self.inner.next_client.fetch_add(1, Ordering::Relaxed)
    }

    /// Publish on behalf of a client (validates the topic).
    pub fn publish(&self, msg: Message) -> Result<usize, String> {
        validate_topic(&msg.topic)?;
        crate::obs::defs::BROKER_MSGS_IN.inc();
        crate::obs::defs::BROKER_BYTES_IN.add(msg.payload.len() as u64);
        let delivered = self.inner.router.lock().unwrap().publish(&msg);
        if delivered > 0 {
            crate::obs::defs::BROKER_MSGS_OUT.add(delivered as u64);
            crate::obs::defs::BROKER_BYTES_OUT.add((delivered * msg.payload.len()) as u64);
        }
        Ok(delivered)
    }

    pub(super) fn subscribe(
        &self,
        client: u64,
        filter: &str,
        tx: std::sync::mpsc::Sender<Message>,
    ) -> Result<(), String> {
        validate_filter(filter)?;
        self.inner.router.lock().unwrap().subscribe(client, filter, tx);
        Ok(())
    }

    pub(super) fn unsubscribe(&self, client: u64, filter: &str) {
        self.inner.router.lock().unwrap().unsubscribe(client, filter);
    }

    pub(super) fn disconnect(&self, client: u64) {
        self.inner.router.lock().unwrap().disconnect(client);
    }

    /// (delivered, dropped) counters.
    pub fn stats(&self) -> (u64, u64) {
        self.inner.router.lock().unwrap().stats()
    }

    /// Active subscription count.
    pub fn subscription_count(&self) -> usize {
        self.inner.router.lock().unwrap().subscription_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn pubsub_roundtrip() {
        let broker = Broker::new();
        let mut sub = broker.connect("sub");
        let pub_ = broker.connect("pub");
        sub.subscribe("fl/+/model").unwrap();
        pub_.publish("fl/3/model", b"params".to_vec()).unwrap();
        let msg = sub.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(msg.topic, "fl/3/model");
        assert_eq!(&**msg.payload, b"params");
    }

    #[test]
    fn publish_to_wildcard_rejected() {
        let broker = Broker::new();
        let c = broker.connect("c");
        assert!(c.publish("a/+", vec![]).is_err());
    }

    #[test]
    fn drop_disconnects() {
        let broker = Broker::new();
        {
            let mut c = broker.connect("temp");
            c.subscribe("x").unwrap();
            assert_eq!(broker.subscription_count(), 1);
        }
        assert_eq!(broker.subscription_count(), 0);
    }

    #[test]
    fn cross_thread_delivery() {
        let broker = Broker::new();
        let mut sub = broker.connect("sub");
        sub.subscribe("work/#").unwrap();
        let b2 = broker.clone();
        let t = std::thread::spawn(move || {
            let p = b2.connect("worker");
            for i in 0..100 {
                p.publish(format!("work/{i}"), vec![i as u8]).unwrap();
            }
        });
        let mut got = 0;
        while got < 100 {
            sub.recv_timeout(Duration::from_secs(2)).unwrap();
            got += 1;
        }
        t.join().unwrap();
        assert_eq!(broker.stats().0, 100);
    }
}
