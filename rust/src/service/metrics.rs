//! Service observability: every phase transition, round outcome and
//! placement score flows through a [`Recorder`]. The CSV sink gives the
//! live tier the same paper-trail the sim tiers got in PRs 3–5; the noop
//! sink keeps tests and hot paths allocation-light.
//!
//! CSV schema (stable — CI asserts the header):
//!
//! | column      | meaning                                               |
//! |-------------|-------------------------------------------------------|
//! | `session`   | session name                                          |
//! | `seq`       | per-session monotonic event number                    |
//! | `kind`      | `phase` \| `round` \| `score`                         |
//! | `round`     | round index (empty for phase events)                  |
//! | `strategy`  | placement strategy name                               |
//! | `placement` | aggregator ids joined with `|` (round/score events)   |
//! | `delay_s`   | round delay / placement score in virtual seconds      |
//! | `detail`    | transition `from->to (reason)`, loss, or free text    |

use crate::metrics::CsvWriter;
use std::io::Write;
use std::path::Path;

/// The stable column set of the CSV sink.
pub const CSV_SCHEMA: [&str; 8] = [
    "session",
    "seq",
    "kind",
    "round",
    "strategy",
    "placement",
    "delay_s",
    "detail",
];

/// One service event, shaped for the CSV sink.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricRow {
    pub session: String,
    /// Monotonic per-session event number (assigned by the runner).
    pub seq: usize,
    /// `"phase"`, `"round"` or `"score"`.
    pub kind: &'static str,
    pub round: Option<usize>,
    pub strategy: String,
    pub placement: Vec<usize>,
    pub delay_s: Option<f64>,
    pub detail: String,
}

impl MetricRow {
    /// Render into the [`CSV_SCHEMA`] column order.
    pub fn to_fields(&self) -> [String; 8] {
        let placement: Vec<String> = self.placement.iter().map(|c| c.to_string()).collect();
        [
            self.session.clone(),
            self.seq.to_string(),
            self.kind.to_string(),
            self.round.map(|r| r.to_string()).unwrap_or_default(),
            self.strategy.clone(),
            placement.join("|"),
            self.delay_s.map(|d| format!("{d:.6}")).unwrap_or_default(),
            self.detail.clone(),
        ]
    }
}

/// A sink for service events. Implementations only need `Send` — the
/// server owns its recorder and feeds it rows in deterministic
/// (submission) order after sessions drain.
pub trait Recorder: Send {
    fn name(&self) -> &'static str;
    fn record(&mut self, row: &MetricRow) -> std::io::Result<()>;
    fn flush(&mut self) -> std::io::Result<()>;
}

/// Discards rows, counting them (tests assert flow without I/O).
#[derive(Debug, Default)]
pub struct NoopRecorder {
    rows: usize,
}

impl NoopRecorder {
    pub fn new() -> NoopRecorder {
        NoopRecorder::default()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
}

impl Recorder for NoopRecorder {
    fn name(&self) -> &'static str {
        "noop"
    }

    fn record(&mut self, _row: &MetricRow) -> std::io::Result<()> {
        self.rows += 1;
        Ok(())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Streams rows into a CSV file with the [`CSV_SCHEMA`] header.
pub struct CsvRecorder<W: Write> {
    writer: CsvWriter<W>,
}

impl CsvRecorder<std::io::BufWriter<std::fs::File>> {
    /// Create `path` (parents included) and write the schema header.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        Ok(CsvRecorder {
            writer: CsvWriter::create(path, &CSV_SCHEMA)?,
        })
    }
}

impl<W: Write> CsvRecorder<W> {
    /// Wrap any writer (tests use `Vec<u8>`).
    pub fn new(out: W) -> std::io::Result<Self> {
        Ok(CsvRecorder {
            writer: CsvWriter::new(out, &CSV_SCHEMA)?,
        })
    }
}

impl<W: Write + Send> Recorder for CsvRecorder<W> {
    fn name(&self) -> &'static str {
        "csv"
    }

    fn record(&mut self, row: &MetricRow) -> std::io::Result<()> {
        self.writer.write_row(&row.to_fields())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.writer.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(kind: &'static str) -> MetricRow {
        MetricRow {
            session: "s0".into(),
            seq: 3,
            kind,
            round: Some(2),
            strategy: "pso".into(),
            placement: vec![4, 0, 9],
            delay_s: Some(1.25),
            detail: "round 2 completed".into(),
        }
    }

    #[test]
    fn csv_rows_follow_the_schema() {
        let mut buf = Vec::new();
        {
            let mut rec = CsvRecorder::new(&mut buf).unwrap();
            rec.record(&row("round")).unwrap();
            let mut phase = row("phase");
            phase.round = None;
            phase.placement.clear();
            phase.delay_s = None;
            phase.detail = "standby->rendezvous (submitted)".into();
            rec.record(&phase).unwrap();
            rec.flush().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), CSV_SCHEMA.join(","));
        assert_eq!(lines.next().unwrap(), "s0,3,round,2,pso,4|0|9,1.250000,round 2 completed");
        assert_eq!(
            lines.next().unwrap(),
            "s0,3,phase,,pso,,,standby->rendezvous (submitted)"
        );
    }

    #[test]
    fn noop_recorder_counts_rows() {
        let mut rec = NoopRecorder::new();
        rec.record(&row("score")).unwrap();
        rec.record(&row("round")).unwrap();
        rec.flush().unwrap();
        assert_eq!(rec.rows(), 2);
        assert_eq!(rec.name(), "noop");
    }
}
