//! How a session's rounds actually execute. The state machine and the
//! session runner are backend-agnostic: [`EnvBackend`] scores placements
//! through a simulation-tier [`Environment`] oracle (artifact-free —
//! what the integration tests and `repro serve --env ...` use), while
//! [`LiveBackend`] drives real FL rounds through the policy-free
//! `Coordinator::execute_round` primitive over a *shared* broker — the
//! multiplexing that makes `repro compare --env live --replicates R`
//! real.

use crate::broker::Broker;
use crate::configio::DeployScenario;
use crate::fl::{Coordinator, Deployment};
use crate::placement::{Environment, Placement};
use crate::runtime::{CheckpointMeta, ModelRuntime};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Duration;

/// What one executed round produced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundOutcome {
    /// Measured (or simulated) round delay in seconds — the fitness
    /// signal fed back to the placement optimizer.
    pub delay_s: f64,
    /// Global-model eval loss after the round (NaN when the backend has
    /// no model, e.g. simulation oracles).
    pub loss: f64,
}

/// Round execution behind the session state machine.
pub trait RoundBackend: Send {
    /// Backend label for storage fingerprints and logs.
    fn label(&self) -> &str;

    /// Block until the backend's clients are reachable (live backends
    /// wait on the join barrier; oracles are always ready).
    fn rendezvous(&mut self, _clients: usize, _timeout: Duration) -> Result<()> {
        Ok(())
    }

    /// Execute round `round` with `placement` under the `active`
    /// liveness mask and return its outcome.
    fn run_round(
        &mut self,
        round: usize,
        placement: &Placement,
        active: &[bool],
    ) -> Result<RoundOutcome>;

    /// Stamp the strategy label on subsequent round records.
    fn set_strategy_label(&mut self, _label: &str) {}

    /// Snapshot the global model (empty when the backend has none).
    fn params(&self) -> Vec<f32> {
        Vec::new()
    }

    /// Install a restored global model (no-op for model-free backends).
    fn install_params(&mut self, _params: Vec<f32>, _round: usize, _loss: f64) -> Result<()> {
        Ok(())
    }

    /// Real per-client heartbeats observed since the last call (`None`
    /// when the backend has no liveness signal of its own — sim
    /// backends, where the dynamics realization already drives the
    /// machine's heartbeat table).
    fn heartbeats(&mut self) -> Option<Vec<bool>> {
        None
    }

    /// Release backend resources (join agent threads etc.).
    fn shutdown(&mut self) {}
}

/// Simulation-tier backend: each round is one oracle evaluation. The
/// `active` mask is ignored for *scoring* — the event-driven oracle
/// models dynamics internally from the same `DynamicsSpec` — but the
/// mask still drives the machine's heartbeat table, so sim and live
/// sessions walk identical phase sequences.
pub struct EnvBackend {
    env: Box<dyn Environment>,
}

impl EnvBackend {
    pub fn new(env: Box<dyn Environment>) -> EnvBackend {
        EnvBackend { env }
    }
}

impl RoundBackend for EnvBackend {
    fn label(&self) -> &str {
        self.env.name()
    }

    fn run_round(
        &mut self,
        round: usize,
        placement: &Placement,
        _active: &[bool],
    ) -> Result<RoundOutcome> {
        let delay_s = self
            .env
            .eval(placement)
            .map_err(|e| anyhow!("round {round}: {e}"))?;
        Ok(RoundOutcome { delay_s, loss: f64::NAN })
    }
}

/// Live backend: agents on threads + a coordinator, all over a broker
/// shared with every other live session (topics are session-scoped).
/// Rounds run through `Coordinator::execute_round_with_membership`, so
/// a `--dynamics` realization filters the round's trainer lists.
pub struct LiveBackend {
    coordinator: Coordinator,
    handles: Vec<std::thread::JoinHandle<()>>,
    client_count: usize,
}

impl LiveBackend {
    /// Wire this session's agents + coordinator onto `broker`.
    pub fn launch(
        scenario: &DeployScenario,
        session: &str,
        runtime: Arc<ModelRuntime>,
        broker: &Broker,
        time_scale: f64,
    ) -> Result<LiveBackend> {
        let (coordinator, handles) =
            Deployment::wire(scenario, session, runtime, broker, time_scale)?;
        Ok(LiveBackend {
            coordinator,
            handles,
            client_count: scenario.clients.len(),
        })
    }

    /// The per-round records accumulated so far (fig4-style reporting).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coordinator
    }
}

impl RoundBackend for LiveBackend {
    fn label(&self) -> &str {
        "live"
    }

    fn rendezvous(&mut self, clients: usize, timeout: Duration) -> Result<()> {
        self.coordinator
            .wait_for_clients(clients.min(self.client_count), timeout)
    }

    fn run_round(
        &mut self,
        round: usize,
        placement: &Placement,
        active: &[bool],
    ) -> Result<RoundOutcome> {
        let rec = self
            .coordinator
            .execute_round_with_membership(round, placement, Some(active))?;
        Ok(RoundOutcome {
            delay_s: rec.delay.as_secs_f64(),
            loss: rec.loss,
        })
    }

    fn set_strategy_label(&mut self, label: &str) {
        self.coordinator.set_strategy_label(label);
    }

    fn params(&self) -> Vec<f32> {
        self.coordinator.global_model().to_vec()
    }

    fn heartbeats(&mut self) -> Option<Vec<bool>> {
        Some(self.coordinator.take_heartbeats())
    }

    fn install_params(&mut self, params: Vec<f32>, round: usize, loss: f64) -> Result<()> {
        let meta = CheckpointMeta {
            param_count: params.len(),
            round,
            session: String::new(),
            loss,
            optimizer: None,
        };
        self.coordinator.install_checkpoint(params, &meta)
    }

    fn shutdown(&mut self) {
        self.coordinator.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::EmulatedDelay;

    fn backend() -> EnvBackend {
        let sc = DeployScenario::paper_docker();
        EnvBackend::new(Box::new(EmulatedDelay::from_scenario(&sc)))
    }

    #[test]
    fn env_backend_scores_deterministically() {
        let sc = DeployScenario::paper_docker();
        let p = Placement::new(vec![0, 1, 2]);
        let active = vec![true; sc.clients.len()];
        let mut a = backend();
        let mut b = backend();
        let oa = a.run_round(0, &p, &active).unwrap();
        let ob = b.run_round(0, &p, &active).unwrap();
        assert!(oa.delay_s > 0.0);
        assert_eq!(oa.delay_s.to_bits(), ob.delay_s.to_bits(), "oracle must be deterministic");
        assert!(oa.loss.is_nan(), "oracles have no model");
        // The default trait plumbing is inert for model-free backends.
        assert!(a.params().is_empty());
        assert!(a.heartbeats().is_none(), "oracles have no liveness feed");
        a.install_params(Vec::new(), 0, f64::NAN).unwrap();
        a.rendezvous(10, Duration::from_secs(1)).unwrap();
        a.shutdown();
    }

    #[test]
    fn env_backend_rejects_invalid_placements() {
        let mut b = backend();
        // Duplicate client in two slots: the oracle validates.
        let bad = Placement::new(vec![0, 0, 1]);
        assert!(b.run_round(0, &bad, &[true; 10]).is_err());
    }
}
