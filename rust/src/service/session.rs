//! One FL session end to end: spec → optimizer + machine + backend →
//! round loop → outcome. The runner is the glue between the pure
//! [`SessionMachine`] and a [`RoundBackend`]: it draws one dynamics
//! realization and one placement proposal per round (held across
//! retries, so the RNG streams stay replay-exact), heartbeats the
//! machine from the realization's liveness mask, persists a
//! [`SessionSnapshot`] after *every* completed round, and emits every
//! phase edge / round outcome / best-so-far score as [`MetricRow`]s.
//!
//! ## Resume = replay
//!
//! Optimizer RNG state is not serialized. Instead, a resumed runner
//! rebuilds its optimizer from the seed under the canonical seeding
//! discipline and *replays* the persisted trace — one realization + one
//! proposal + one feedback per completed round, asserting each replayed
//! placement matches the recorded one — which leaves the optimizer
//! (including its RNG) bit-identical to the moment the snapshot was
//! taken. A torn save or an edited spec shows up as a replay divergence
//! error instead of silently mixing rounds.

use super::backend::{EnvBackend, LiveBackend, RoundBackend, RoundOutcome};
use super::machine::{MachineConfig, Phase, SessionMachine};
use super::metrics::MetricRow;
use super::storage::{SessionSnapshot, SpecSummary, Store, TraceRow};
use crate::configio::{DeployScenario, DynamicsSpec, SimScenario};
use crate::des::Dynamics;
use crate::fault::{apply_heartbeat_loss, FaultPlan, FaultyBackend};
use crate::fitness::ClientAttrs;
use crate::obs::defs as obs;
use crate::placement::{registry, Optimizer, Placement, Stepwise};
use crate::prng::Pcg32;
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Stable per-session trace lane (Chrome `tid`) from the session name —
/// spans from concurrent sessions land on distinct Perfetto rows.
fn trace_lane(name: &str) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for b in name.bytes() {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h % 997
}

/// Salt separating the runner's dynamics stream from the optimizer /
/// population streams derived from the same session seed.
const DYNAMICS_SALT: u64 = 0x4459_4E41; // "DYNA"

/// What a session runs against.
#[derive(Debug, Clone)]
pub enum SessionKind {
    /// Simulation tier: rounds are oracle evaluations (artifact-free).
    Env {
        sim: SimScenario,
        /// Registry environment name (`analytic` / `event-driven`).
        env: String,
    },
    /// Live tier: rounds are real FL rounds over the shared broker.
    Live {
        deploy: DeployScenario,
        /// Emulated-clock compression factor for agent think time.
        time_scale: f64,
    },
}

/// A submitted session: everything the service needs to build a runner.
#[derive(Debug, Clone)]
pub struct SessionSpec {
    /// Unique, path-safe session name (also the storage key).
    pub name: String,
    /// Placement strategy (a `placement::registry` name, aliases ok).
    pub strategy: String,
    /// FL rounds the session must complete.
    pub rounds: usize,
    /// Seed override; defaults to the scenario's own seed.
    pub seed: Option<u64>,
    pub kind: SessionKind,
    /// Per-round membership dynamics replayed into the session (`None`
    /// = static membership). This is the `--dynamics` path: the same
    /// churn/dropout machinery the DES tier models internally, here
    /// realized once per round and fed to the machine's heartbeat table
    /// (and, live, into the round's trainer lists).
    pub dynamics: Option<DynamicsSpec>,
    /// Override for [`MachineConfig::retry_budget`].
    pub retry_budget: Option<usize>,
}

impl SessionSpec {
    /// An env-backed session over `sim`, named `name`.
    pub fn env(name: &str, strategy: &str, rounds: usize, sim: SimScenario, env: &str) -> Self {
        SessionSpec {
            name: name.to_string(),
            strategy: strategy.to_string(),
            rounds,
            seed: None,
            kind: SessionKind::Env { sim, env: env.to_string() },
            dynamics: None,
            retry_budget: None,
        }
    }

    /// A live session over `deploy`, named `name`.
    pub fn live(
        name: &str,
        strategy: &str,
        rounds: usize,
        deploy: DeployScenario,
        time_scale: f64,
    ) -> Self {
        SessionSpec {
            name: name.to_string(),
            strategy: strategy.to_string(),
            rounds,
            seed: None,
            kind: SessionKind::Live { deploy, time_scale },
            dynamics: None,
            retry_budget: None,
        }
    }

    /// The seed this session actually runs under.
    pub fn effective_seed(&self) -> u64 {
        let scenario_seed = match &self.kind {
            SessionKind::Env { sim, .. } => sim.seed,
            SessionKind::Live { deploy, .. } => deploy.seed,
        };
        self.seed.unwrap_or(scenario_seed)
    }

    pub fn client_count(&self) -> usize {
        match &self.kind {
            SessionKind::Env { sim, .. } => sim.client_count(),
            SessionKind::Live { deploy, .. } => deploy.clients.len(),
        }
    }

    /// Aggregator slot count (placement dimensionality, Eq. 5).
    pub fn dims(&self) -> usize {
        match &self.kind {
            SessionKind::Env { sim, .. } => sim.dimensions(),
            SessionKind::Live { deploy, .. } => deploy.dimensions(),
        }
    }

    /// Reject inconsistent specs before any resources are built.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            return Err(anyhow!("session spec: name must be non-empty"));
        }
        if self.rounds == 0 {
            return Err(anyhow!("session {}: rounds must be >= 1", self.name));
        }
        registry::canonical(&self.strategy).map_err(|e| anyhow!("session {}: {e}", self.name))?;
        match &self.kind {
            SessionKind::Env { sim, env } => {
                registry::canonical_env(env).map_err(|e| anyhow!("session {}: {e}", self.name))?;
                sim.des.validate().map_err(|e| anyhow!("session {}: {e}", self.name))?;
            }
            SessionKind::Live { deploy, time_scale } => {
                deploy.validate().map_err(|e| anyhow!("session {}: {e}", self.name))?;
                // 0.0 = no emulated slowdown (the fast-test mode).
                if *time_scale < 0.0 || !time_scale.is_finite() {
                    return Err(anyhow!(
                        "session {}: time_scale must be finite and >= 0, got {time_scale}",
                        self.name
                    ));
                }
            }
        }
        if self.client_count() < self.dims() {
            return Err(anyhow!(
                "session {}: {} clients cannot host {} aggregator slots",
                self.name,
                self.client_count(),
                self.dims()
            ));
        }
        Ok(())
    }
}

/// The in-flight round's work item, held across retries so a retried
/// round re-runs the *same* placement under the *same* realization —
/// the invariant that keeps resume-by-replay exact (replay consumes one
/// realization + one proposal per completed round, never more).
struct PendingRound {
    round: usize,
    placement: Placement,
    active: Vec<bool>,
    /// Heartbeat-live clients when the round was drawn.
    live: usize,
}

/// The result of driving one session to a stopping point.
#[derive(Debug)]
pub struct SessionOutcome {
    pub name: String,
    /// Canonical strategy name.
    pub strategy: String,
    /// Phase the session stopped in (`Finished`, `Failed`, or a
    /// mid-flight `Round(k)` when a round limit paused it).
    pub phase: Phase,
    /// Every completed round, oldest first (includes restored rounds).
    pub trace: Vec<TraceRow>,
    /// Metric rows emitted by this incarnation, in order.
    pub rows: Vec<MetricRow>,
    /// Optimizer's best placement + delay at stop time.
    pub best: Option<(Placement, f64)>,
    /// `Some(k)` when this incarnation resumed with rounds `0..k`
    /// restored from storage.
    pub resumed_from: Option<usize>,
}

/// Drives one session: machine + optimizer + backend + dynamics.
pub struct SessionRunner {
    spec: SessionSpec,
    summary: SpecSummary,
    machine: SessionMachine,
    stepwise: Stepwise,
    backend: Box<dyn RoundBackend>,
    dynamics: Dynamics,
    trace: Vec<TraceRow>,
    rows: Vec<MetricRow>,
    /// Per-incarnation monotonic event number (restarts at 0 on resume).
    seq: usize,
    resumed_from: Option<usize>,
    pending: Option<PendingRound>,
    /// Machine transitions already turned into metric rows.
    transitions_emitted: usize,
    /// Deterministic fault plan (heartbeat loss lives here; round faults
    /// are injected by the [`FaultyBackend`] wrapper installed by
    /// [`SessionRunner::with_faults`]).
    faults: Option<Arc<FaultPlan>>,
}

impl SessionRunner {
    /// Build an env-backed runner. The oracle and the optimizer share
    /// the canonical seeding discipline (`run_cell_trial`'s contract):
    /// population sampled first from the seed, optimizer stream split
    /// off after — so a service session scores exactly like a `repro
    /// sim` trial of the same scenario + seed.
    pub fn new_env(spec: SessionSpec, snapshot: Option<SessionSnapshot>) -> Result<SessionRunner> {
        spec.validate()?;
        let SessionKind::Env { sim, env } = &spec.kind else {
            return Err(anyhow!("session {}: new_env needs an Env spec", spec.name));
        };
        let mut sim = sim.clone();
        sim.seed = spec.effective_seed();
        let mut rng = Pcg32::seed_from_u64(sim.seed);
        let attrs = ClientAttrs::sample_population(
            sim.client_count(),
            sim.pspeed_range,
            sim.memcap_range,
            sim.mdatasize,
            &mut rng,
        );
        let opt = registry::build_sim(&spec.strategy, &sim, rng.split())
            .map_err(|e| anyhow!("session {}: {e}", spec.name))?;
        let oracle = registry::build_sim_env(env, &sim, attrs)
            .map_err(|e| anyhow!("session {}: {e}", spec.name))?;
        SessionRunner::build(spec, opt, Box::new(EnvBackend::new(oracle)), snapshot)
    }

    /// Build a live runner over an already-wired [`LiveBackend`] (the
    /// server wires agents + coordinator onto the shared broker first).
    /// Live optimizers follow the Fig-4 convention: steady-state
    /// strategy variants seeded from `seed ^ 0xABCD`.
    pub fn new_live(
        spec: SessionSpec,
        backend: LiveBackend,
        snapshot: Option<SessionSnapshot>,
    ) -> Result<SessionRunner> {
        spec.validate()?;
        let SessionKind::Live { deploy, .. } = &spec.kind else {
            return Err(anyhow!("session {}: new_live needs a Live spec", spec.name));
        };
        let opt = registry::build_live(
            &spec.strategy,
            deploy.dimensions(),
            deploy.clients.len(),
            deploy.pso,
            spec.effective_seed() ^ 0xABCD,
        )
        .map_err(|e| anyhow!("session {}: {e}", spec.name))?;
        SessionRunner::build(spec, opt, Box::new(backend), snapshot)
    }

    fn build(
        spec: SessionSpec,
        opt: Box<dyn Optimizer>,
        backend: Box<dyn RoundBackend>,
        snapshot: Option<SessionSnapshot>,
    ) -> Result<SessionRunner> {
        let cc = spec.client_count();
        let mut cfg = MachineConfig::for_session(spec.rounds, cc, spec.dims());
        if let Some(budget) = spec.retry_budget {
            cfg.retry_budget = budget;
        }
        let machine = SessionMachine::new(cfg).map_err(|e| anyhow!("session {}: {e}", spec.name))?;
        let stepwise = Stepwise::new(opt);
        let seed = spec.effective_seed();
        let dynamics = match &spec.dynamics {
            Some(d) => Dynamics::new(d.clone(), Pcg32::seed_from_u64(seed ^ DYNAMICS_SALT)),
            None => Dynamics::off(),
        };
        let summary = SpecSummary {
            strategy: stepwise.name().to_string(),
            rounds: spec.rounds,
            seed,
            client_count: cc,
            dims: spec.dims(),
            backend: backend.label().to_string(),
        };
        let mut runner = SessionRunner {
            spec,
            summary,
            machine,
            stepwise,
            backend,
            dynamics,
            trace: Vec::new(),
            rows: Vec::new(),
            seq: 0,
            resumed_from: None,
            pending: None,
            transitions_emitted: 0,
            faults: None,
        };
        if let Some(snap) = snapshot {
            runner.restore(snap)?;
        }
        Ok(runner)
    }

    /// Attach a deterministic fault plan: round execution goes through a
    /// [`FaultyBackend`] wrapper and the per-round heartbeat masks get
    /// plan-driven loss applied. Called *after* build/restore — replay
    /// never runs rounds, so restored sessions replay clean and only
    /// fresh rounds see injected faults.
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> SessionRunner {
        struct Swapping;
        impl RoundBackend for Swapping {
            fn label(&self) -> &str {
                "swapping"
            }
            fn run_round(
                &mut self,
                _round: usize,
                _p: &Placement,
                _a: &[bool],
            ) -> Result<RoundOutcome> {
                Err(anyhow!("placeholder backend"))
            }
        }
        let inner = std::mem::replace(&mut self.backend, Box::new(Swapping));
        self.backend = Box::new(FaultyBackend::new(inner, plan.clone(), &self.spec.name));
        self.faults = Some(plan);
        self
    }

    /// The realization's liveness mask with plan-driven heartbeat loss
    /// applied. Loss is telemetry erasure only: the round still executes
    /// under the true membership — only the machine's liveness table
    /// (and therefore quorum) sees the erasures.
    fn lossy_mask(&self, round: usize, mask: &[bool]) -> Vec<bool> {
        match &self.faults {
            Some(plan) => apply_heartbeat_loss(plan, &self.spec.name, round, mask),
            None => mask.to_vec(),
        }
    }

    /// Rebuild this runner's state from a snapshot by replaying its
    /// trace (see the module docs). Hard-errors on any divergence.
    fn restore(&mut self, snap: SessionSnapshot) -> Result<()> {
        let name = &self.spec.name;
        if snap.summary != self.summary {
            return Err(anyhow!(
                "session {name}: stored spec {:?} does not match submitted spec {:?}",
                snap.summary,
                self.summary
            ));
        }
        if snap.next_round != snap.trace.len() {
            return Err(anyhow!(
                "session {name}: torn snapshot (next_round {} but {} trace rows)",
                snap.next_round,
                snap.trace.len()
            ));
        }
        self.machine
            .resume_at(snap.next_round)
            .map_err(|e| anyhow!("session {name}: {e}"))?;
        let cc = self.spec.client_count();
        for row in &snap.trace {
            let _realization = self.dynamics.next_round(cc);
            let p = self.stepwise.propose(row.round);
            if p.as_slice() != row.placement.as_slice() {
                return Err(anyhow!(
                    "session {name}: replay diverged at round {} \
                     (replayed {:?}, stored {:?}) — snapshot from a different spec/seed?",
                    row.round,
                    p.as_slice(),
                    row.placement
                ));
            }
            self.stepwise.feedback(row.delay_s);
        }
        // Cross-check the replayed optimizer against the stored snapshot
        // — a torn save (newer checkpoint under an older state.json)
        // lands here instead of silently mixing rounds.
        if let Some(stored) = &snap.optimizer {
            let replayed = self.stepwise.optimizer().state();
            if replayed != *stored {
                // A torn save (newer checkpoint under an older
                // state.json or vice versa) lands here. state.json is
                // the commit point and the trace replayed cleanly above,
                // so the replayed optimizer is authoritative — recover
                // instead of refusing to resume.
                crate::log_warn!(
                    "service",
                    "session {}: stored optimizer state disagrees with trace replay \
                     (torn save) — recovering from the replayed trace at round {}",
                    name,
                    snap.next_round
                );
                let detail =
                    format!("torn save recovered by replay at round {}", snap.next_round);
                self.push_row("phase", None, Vec::new(), None, detail);
            }
        }
        if !snap.params.is_empty() {
            self.backend.install_params(snap.params.clone(), snap.next_round, snap.loss)?;
        }
        self.resumed_from = Some(snap.next_round);
        self.trace = snap.trace;
        Ok(())
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Canonical strategy name (what the outcome will carry).
    pub fn strategy(&self) -> &str {
        &self.summary.strategy
    }

    /// Drive the session until it finishes, fails, or `round_limit`
    /// rounds have been executed *by this incarnation* (a paused
    /// session persists as resumable mid-flight state — how the
    /// kill/restart tests model a dying coordinator). Consumes the
    /// runner; every completed round is persisted to `store` before the
    /// next one starts.
    pub fn run(mut self, store: &dyn Store, round_limit: Option<usize>) -> Result<SessionOutcome> {
        let cc = self.spec.client_count();
        let rendezvous_timeout = self.machine.config().rendezvous_timeout;
        self.machine.submit().map_err(|e| anyhow!("session {}: {e}", self.spec.name))?;
        self.emit_phases();
        let strategy = self.summary.strategy.clone();
        self.backend.set_strategy_label(&strategy);
        match self.backend.rendezvous(cc, Duration::from_secs_f64(rendezvous_timeout)) {
            Ok(()) => {
                self.machine.beat_active(&vec![true; cc]);
                self.machine
                    .rendezvous_complete()
                    .map_err(|e| anyhow!("session {}: {e}", self.spec.name))?;
            }
            Err(e) => {
                let why = format!("rendezvous: {e:#}");
                self.machine.fail(&why);
            }
        }
        self.emit_phases();

        let mut executed = 0usize;
        while let Phase::Round(k) = self.machine.phase() {
            if round_limit.is_some_and(|limit| executed >= limit) {
                break;
            }
            // Draw this round's work item once; retries reuse it.
            if self.pending.as_ref().map(|p| p.round) != Some(k) {
                let realization = self.dynamics.next_round(cc);
                let placement = self.stepwise.propose(k);
                let beats = self.lossy_mask(k, &realization.active);
                self.machine.beat_active(&beats);
                let live = self.machine.live_clients();
                obs::SERVICE_HEARTBEAT_MISSES.add(self.machine.stale_clients() as u64);
                self.pending =
                    Some(PendingRound { round: k, placement, active: realization.active, live });
            }
            let pending = self.pending.as_ref().expect("pending round just ensured");
            if !self.machine.has_quorum() {
                let live = self.machine.live_clients();
                let why = format!("quorum lost ({live}/{} live)", self.machine.config().quorum);
                self.machine
                    .round_failed(&why)
                    .map_err(|e| anyhow!("session {}: {e}", self.spec.name))?;
                self.emit_phases();
                continue;
            }
            match self.backend.run_round(k, &pending.placement, &pending.active) {
                Ok(out) => {
                    // Live backends observed real per-client heartbeats
                    // during the round; fold them (loss-filtered) into
                    // the machine's liveness table so the next quorum
                    // check runs on observed liveness, not just the
                    // dynamics realization.
                    if let Some(beats) = self.backend.heartbeats() {
                        let beats = self.lossy_mask(k, &beats);
                        self.machine.beat_active(&beats);
                    }
                    let row = TraceRow {
                        round: k,
                        placement: pending.placement.as_slice().to_vec(),
                        delay_s: out.delay_s,
                        loss: out.loss,
                        live: pending.live,
                    };
                    obs::SERVICE_ROUND_DELAY.observe(&strategy, out.delay_s);
                    self.stepwise.feedback(out.delay_s);
                    let round_start = self.machine.now();
                    self.machine
                        .round_completed(out.delay_s)
                        .map_err(|e| anyhow!("session {}: {e}", self.spec.name))?;
                    // One virtual span per round on this session's
                    // trace lane: the machine just advanced its clock
                    // by the measured TPD (the Eq. 6–7 delay), so
                    // [start, now] is exactly the round's extent on
                    // the DES time axis.
                    if crate::obs::tracing_enabled() {
                        crate::obs::record_virtual(
                            "round",
                            "service",
                            trace_lane(&self.spec.name),
                            round_start,
                            self.machine.now(),
                            Some(format!("{} {} r{k}", self.spec.name, strategy)),
                        );
                    }
                    self.trace.push(row);
                    self.pending = None;
                    executed += 1;
                    self.persist(store)?;
                    self.emit_round_rows(k);
                }
                Err(e) => {
                    let why = format!("{e:#}");
                    self.machine
                        .round_failed(&why)
                        .map_err(|e| anyhow!("session {}: {e}", self.spec.name))?;
                }
            }
            self.emit_phases();
        }

        if self.machine.phase() == Phase::Finishing {
            self.machine.drained().map_err(|e| anyhow!("session {}: {e}", self.spec.name))?;
        }
        // Persist the terminal (or paused) phase so storage reflects it.
        self.persist(store)?;
        self.emit_phases();
        self.backend.shutdown();
        Ok(SessionOutcome {
            name: self.spec.name,
            strategy,
            phase: self.machine.phase(),
            trace: self.trace,
            rows: self.rows,
            best: self.stepwise.optimizer().best(),
            resumed_from: self.resumed_from,
        })
    }

    fn persist(&self, store: &dyn Store) -> Result<()> {
        let snap = SessionSnapshot {
            summary: self.summary.clone(),
            next_round: self.trace.len(),
            phase: self.machine.phase().to_string(),
            trace: self.trace.clone(),
            optimizer: Some(self.stepwise.optimizer().state()),
            params: self.backend.params(),
            loss: self.trace.last().map(|r| r.loss).unwrap_or(f64::NAN),
        };
        let started = Instant::now();
        let result = store.save(&self.spec.name, &snap);
        obs::STORE_SAVE.observe(started.elapsed().as_secs_f64());
        result
    }

    /// Emit the round-outcome row and the best-so-far score row for a
    /// just-completed round `k`.
    fn emit_round_rows(&mut self, k: usize) {
        let row = self.trace.last().expect("round just pushed").clone();
        let detail = format!("live {}", row.live);
        self.push_row("round", Some(k), row.placement, Some(row.delay_s), detail);
        if let Some((best, delay)) = self.stepwise.optimizer().best() {
            let detail = "best so far".to_string();
            self.push_row("score", Some(k), best.as_slice().to_vec(), Some(delay), detail);
        }
    }

    /// Turn machine transitions not yet reported into phase rows.
    fn emit_phases(&mut self) {
        let fresh = self.machine.transitions()[self.transitions_emitted..].to_vec();
        self.transitions_emitted += fresh.len();
        for t in fresh {
            let detail = format!("{}->{} ({})", t.from, t.to, t.reason);
            self.push_row("phase", None, Vec::new(), None, detail);
        }
    }

    fn push_row(
        &mut self,
        kind: &'static str,
        round: Option<usize>,
        placement: Vec<usize>,
        delay_s: Option<f64>,
        detail: String,
    ) {
        self.rows.push(MetricRow {
            session: self.spec.name.clone(),
            seq: self.seq,
            kind,
            round,
            strategy: self.summary.strategy.clone(),
            placement,
            delay_s,
            detail,
        });
        self.seq += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::RoundOutcome;
    use super::super::storage::NoopStore;
    use super::*;

    fn tiny_sim() -> SimScenario {
        let mut sc = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        sc.pso.particles = 4;
        sc.pso.iterations = 8;
        sc
    }

    fn env_spec(name: &str, strategy: &str, rounds: usize) -> SessionSpec {
        let mut spec = SessionSpec::env(name, strategy, rounds, tiny_sim(), "analytic");
        // Dropout stresses the dynamics/replay alignment invariants.
        spec.dynamics = Some(DynamicsSpec { dropout_prob: 0.3, ..DynamicsSpec::default() });
        spec
    }

    fn delays(trace: &[TraceRow]) -> Vec<u64> {
        trace.iter().map(|r| r.delay_s.to_bits()).collect()
    }

    #[test]
    fn env_session_finishes_deterministically() {
        let store = NoopStore::new();
        let a = SessionRunner::new_env(env_spec("a", "pso", 6), None)
            .unwrap()
            .run(&store, None)
            .unwrap();
        let b = SessionRunner::new_env(env_spec("b", "pso", 6), None)
            .unwrap()
            .run(&store, None)
            .unwrap();
        assert_eq!(a.phase, Phase::Finished);
        assert_eq!(a.trace.len(), 6);
        assert_eq!(a.strategy, "pso");
        for (i, row) in a.trace.iter().enumerate() {
            assert_eq!(row.round, i);
            assert!(row.delay_s.is_finite() && row.delay_s > 0.0);
            assert!(row.live >= 1, "live-count floor");
        }
        // Same spec (different name) → bit-identical trace.
        assert_eq!(delays(&a.trace), delays(&b.trace));
        assert_eq!(a.best.unwrap().1, b.best.unwrap().1);
        // Storage saw every completed round plus the terminal phase.
        let snap = store.load("a").unwrap().unwrap();
        assert_eq!(snap.next_round, 6);
        assert_eq!(snap.phase, "finished");
    }

    #[test]
    fn runner_emits_round_score_and_phase_rows() {
        let store = NoopStore::new();
        let out = SessionRunner::new_env(env_spec("rows", "round-robin", 4), None)
            .unwrap()
            .run(&store, None)
            .unwrap();
        let count = |kind: &str| out.rows.iter().filter(|r| r.kind == kind).count();
        assert_eq!(count("round"), 4);
        assert_eq!(count("score"), 4);
        // submitted → rendezvous-complete → 4 round edges → drained.
        assert_eq!(count("phase"), 7);
        for (i, row) in out.rows.iter().enumerate() {
            assert_eq!(row.seq, i, "seq must be monotonic");
            assert_eq!(row.session, "rows");
            assert_eq!(row.strategy, "round-robin");
        }
        assert!(out.rows[0].detail.contains("standby->rendezvous"));
        assert!(out.rows.last().unwrap().detail.contains("->finished"));
    }

    #[test]
    fn paused_session_resumes_to_a_bit_identical_trace() {
        // Reference: one uninterrupted 6-round session.
        let store = NoopStore::new();
        let full = SessionRunner::new_env(env_spec("ref", "pso", 6), None)
            .unwrap()
            .run(&store, None)
            .unwrap();
        // Same spec, paused after 3 rounds (mid PSO batch), resumed from
        // the snapshot by a fresh runner — the kill/restart shape.
        let paused = SessionRunner::new_env(env_spec("kr", "pso", 6), None)
            .unwrap()
            .run(&store, Some(3))
            .unwrap();
        assert_eq!(paused.phase, Phase::Round(3));
        assert_eq!(paused.trace.len(), 3);
        let snap = store.load("kr").unwrap().unwrap();
        assert_eq!(snap.next_round, 3);
        assert_eq!(snap.phase, "round(3)");
        let resumed = SessionRunner::new_env(env_spec("kr", "pso", 6), Some(snap))
            .unwrap()
            .run(&store, None)
            .unwrap();
        assert_eq!(resumed.phase, Phase::Finished);
        assert_eq!(resumed.resumed_from, Some(3));
        assert_eq!(delays(&resumed.trace), delays(&full.trace), "resume must not re-run or drift");
        assert_eq!(resumed.best.unwrap().1, full.best.unwrap().1);
        // The resume edge is visible in the transition log.
        assert!(resumed.rows.iter().any(|r| r.detail.contains("rounds 0..3 restored")));
    }

    #[test]
    fn resume_rejects_mismatched_specs() {
        let store = NoopStore::new();
        SessionRunner::new_env(env_spec("s", "pso", 6), None)
            .unwrap()
            .run(&store, Some(2))
            .unwrap();
        let snap = store.load("s").unwrap().unwrap();
        // Different strategy → fingerprint mismatch, refused up front.
        let err = SessionRunner::new_env(env_spec("s", "ga", 6), Some(snap.clone()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not match"), "{err}");
        // Tampered trace → replay divergence.
        let mut torn = snap.clone();
        torn.trace[1].placement.reverse();
        let err = SessionRunner::new_env(env_spec("s", "pso", 6), Some(torn))
            .unwrap_err()
            .to_string();
        assert!(err.contains("replay diverged"), "{err}");
        // Inconsistent next_round → torn snapshot.
        let mut short = snap;
        short.next_round = 1;
        let err = SessionRunner::new_env(env_spec("s", "pso", 6), Some(short))
            .unwrap_err()
            .to_string();
        assert!(err.contains("torn snapshot"), "{err}");
    }

    #[test]
    fn torn_optimizer_snapshot_recovers_by_replay() {
        let store = NoopStore::new();
        SessionRunner::new_env(env_spec("torn", "pso", 6), None)
            .unwrap()
            .run(&store, Some(3))
            .unwrap();
        let mut snap = store.load("torn").unwrap().unwrap();
        // Simulate a torn save: a round-3 state.json half paired with a
        // stale round-2 optimizer checkpoint half.
        let stale_store = NoopStore::new();
        SessionRunner::new_env(env_spec("torn", "pso", 6), None)
            .unwrap()
            .run(&stale_store, Some(2))
            .unwrap();
        let stale = stale_store.load("torn").unwrap().unwrap().optimizer;
        assert_ne!(stale, snap.optimizer, "round-2 vs round-3 optimizer states must differ");
        snap.optimizer = stale;
        // The trace replays cleanly, so the mismatch is recovered (the
        // replayed optimizer is authoritative), not a hard error.
        let resumed = SessionRunner::new_env(env_spec("torn", "pso", 6), Some(snap))
            .unwrap()
            .run(&store, None)
            .unwrap();
        assert_eq!(resumed.phase, Phase::Finished);
        assert!(resumed.rows.iter().any(|r| r.detail.contains("torn save recovered")));
        let full = SessionRunner::new_env(env_spec("full", "pso", 6), None)
            .unwrap()
            .run(&NoopStore::new(), None)
            .unwrap();
        assert_eq!(delays(&resumed.trace), delays(&full.trace), "recovery must be exact");
    }

    #[test]
    fn empty_fault_plan_leaves_a_session_bit_identical() {
        let store = NoopStore::new();
        let plain = SessionRunner::new_env(env_spec("p", "pso", 5), None)
            .unwrap()
            .run(&store, None)
            .unwrap();
        let faulted = SessionRunner::new_env(env_spec("f", "pso", 5), None)
            .unwrap()
            .with_faults(Arc::new(FaultPlan::empty()))
            .run(&store, None)
            .unwrap();
        assert_eq!(faulted.phase, Phase::Finished);
        assert_eq!(delays(&plain.trace), delays(&faulted.trace));
        assert_eq!(plain.best.unwrap().1, faulted.best.unwrap().1);
    }

    /// A backend whose rounds always fail — exercises the retry budget.
    struct BrokenBackend;

    impl RoundBackend for BrokenBackend {
        fn label(&self) -> &str {
            "analytic"
        }

        fn run_round(&mut self, round: usize, _p: &Placement, _a: &[bool]) -> Result<RoundOutcome> {
            Err(anyhow!("injected fault in round {round}"))
        }
    }

    #[test]
    fn round_failures_spend_the_retry_budget_into_failed() {
        let mut spec = env_spec("broken", "round-robin", 3);
        spec.retry_budget = Some(1);
        let opt = registry::build("round-robin", &tiny_sim(), spec.effective_seed()).unwrap();
        let runner = SessionRunner::build(spec, opt, Box::new(BrokenBackend), None).unwrap();
        let store = NoopStore::new();
        let out = runner.run(&store, None).unwrap();
        assert_eq!(out.phase, Phase::Failed);
        assert!(out.trace.is_empty());
        let retries: Vec<&MetricRow> =
            out.rows.iter().filter(|r| r.detail.contains("injected fault")).collect();
        // retry 1/1, then budget exhausted.
        assert_eq!(retries.len(), 2);
        assert!(retries.last().unwrap().detail.contains("budget 1 exhausted"));
        assert_eq!(store.load("broken").unwrap().unwrap().phase, "failed");
    }
}
