//! Pluggable session persistence behind the [`Store`] trait.
//!
//! Two backends ship: [`NoopStore`] (in-memory, for tests and ephemeral
//! serves) and [`DirStore`] (file-backed). `DirStore` layers over
//! `runtime::checkpoint`: the model parameters + optimizer snapshot live
//! in a standard `model.ckpt`, while the session trace and machine phase
//! live next to it in `state.json` — so a killed coordinator resumes
//! every in-flight session from its last completed round, and the
//! checkpoint stays readable by the existing PR 2 tooling.
//!
//! Layout: `root/<session>/state.json` + `root/<session>/model.ckpt`,
//! both written atomically (tmp + rename), checkpoint first so a torn
//! save is detected at load time rather than silently mixing rounds.

use crate::json::{self, Value};
use crate::placement::OptimizerState;
use crate::runtime::checkpoint::{self, CheckpointMeta};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

/// One completed round, as persisted (and replayed on resume).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    pub round: usize,
    /// Aggregator client ids, slot-ordered.
    pub placement: Vec<usize>,
    /// Measured round delay (virtual seconds).
    pub delay_s: f64,
    /// Eval loss after the round (NaN if eval was skipped).
    pub loss: f64,
    /// Live clients when the round started.
    pub live: usize,
}

/// The spec fingerprint a snapshot was produced under. Resume refuses
/// to continue a session whose submitted spec no longer matches.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecSummary {
    pub strategy: String,
    pub rounds: usize,
    pub seed: u64,
    pub client_count: usize,
    /// Aggregator slot count of the hierarchy.
    pub dims: usize,
    /// Backend label (environment name or `live`).
    pub backend: String,
}

/// Everything needed to resume a session: spec fingerprint, machine
/// position, the completed-round trace (replayed to rebuild optimizer
/// RNG state bit-exactly), plus the model/optimizer checkpoint payload.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    pub summary: SpecSummary,
    /// First round the resumed session must execute.
    pub next_round: usize,
    /// Machine phase label at save time (`Phase::to_string`).
    pub phase: String,
    pub trace: Vec<TraceRow>,
    /// Optimizer snapshot (cross-checked against the trace replay).
    pub optimizer: Option<OptimizerState>,
    /// Flat global model (empty for env-backed sessions).
    pub params: Vec<f32>,
    /// Last eval loss (NaN if unknown).
    pub loss: f64,
}

/// Session persistence. `&self` methods — stores are shared across the
/// scheduler's workers behind an `Arc`.
pub trait Store: Send + Sync {
    fn name(&self) -> &'static str;
    fn save(&self, session: &str, snap: &SessionSnapshot) -> Result<()>;
    /// `Ok(None)` when the session has no snapshot.
    fn load(&self, session: &str) -> Result<Option<SessionSnapshot>>;
    /// Names of every stored session, sorted.
    fn sessions(&self) -> Result<Vec<String>>;
    fn remove(&self, session: &str) -> Result<()>;
}

/// In-memory store: survives nothing, costs nothing.
#[derive(Default)]
pub struct NoopStore {
    map: Mutex<BTreeMap<String, SessionSnapshot>>,
}

impl NoopStore {
    pub fn new() -> NoopStore {
        NoopStore::default()
    }
}

impl Store for NoopStore {
    fn name(&self) -> &'static str {
        "noop"
    }

    fn save(&self, session: &str, snap: &SessionSnapshot) -> Result<()> {
        validate_name(session)?;
        self.map.lock().unwrap().insert(session.to_string(), snap.clone());
        Ok(())
    }

    fn load(&self, session: &str) -> Result<Option<SessionSnapshot>> {
        Ok(self.map.lock().unwrap().get(session).cloned())
    }

    fn sessions(&self) -> Result<Vec<String>> {
        Ok(self.map.lock().unwrap().keys().cloned().collect())
    }

    fn remove(&self, session: &str) -> Result<()> {
        self.map.lock().unwrap().remove(session);
        Ok(())
    }
}

/// File-backed store rooted at a directory.
pub struct DirStore {
    root: PathBuf,
}

impl DirStore {
    pub fn open(root: impl Into<PathBuf>) -> Result<DirStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating store root {root:?}"))?;
        Ok(DirStore { root })
    }

    fn session_dir(&self, session: &str) -> Result<PathBuf> {
        validate_name(session)?;
        Ok(self.root.join(session))
    }
}

impl Store for DirStore {
    fn name(&self) -> &'static str {
        "dir"
    }

    fn save(&self, session: &str, snap: &SessionSnapshot) -> Result<()> {
        let dir = self.session_dir(session)?;
        std::fs::create_dir_all(&dir)?;
        // Checkpoint first: `state.json` is the commit point, so a crash
        // between the two writes leaves the previous state.json pointing
        // at a newer ckpt — detected by the resume cross-check instead
        // of silently mixing rounds.
        let meta = CheckpointMeta {
            param_count: snap.params.len(),
            round: snap.next_round,
            session: session.to_string(),
            loss: snap.loss,
            optimizer: snap.optimizer.clone(),
        };
        checkpoint::save(&dir.join("model.ckpt"), &snap.params, &meta)?;
        let state = json::to_string(&state_json(snap));
        let tmp = dir.join("state.json.tmp");
        std::fs::write(&tmp, state)?;
        std::fs::rename(&tmp, dir.join("state.json"))?;
        Ok(())
    }

    fn load(&self, session: &str) -> Result<Option<SessionSnapshot>> {
        let dir = self.session_dir(session)?;
        let state_path = dir.join("state.json");
        if !state_path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&state_path)
            .with_context(|| format!("reading {state_path:?}"))?;
        let v = json::parse(&text).map_err(|e| anyhow!("{state_path:?}: {e}"))?;
        let mut snap = state_from_json(&v).map_err(|e| anyhow!("{state_path:?}: {e}"))?;
        let (params, meta) = checkpoint::load(&dir.join("model.ckpt"))?;
        snap.params = params;
        snap.optimizer = meta.optimizer;
        snap.loss = meta.loss;
        Ok(Some(snap))
    }

    fn sessions(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.root)? {
            let entry = entry?;
            if entry.path().join("state.json").exists() {
                names.push(entry.file_name().to_string_lossy().into_owned());
            }
        }
        names.sort();
        Ok(names)
    }

    fn remove(&self, session: &str) -> Result<()> {
        let dir = self.session_dir(session)?;
        if dir.exists() {
            std::fs::remove_dir_all(&dir)?;
        }
        Ok(())
    }
}

/// Session names become directory names — keep them path-safe.
fn validate_name(session: &str) -> Result<()> {
    if session.is_empty()
        || session.contains('/')
        || session.contains('\\')
        || session.contains("..")
    {
        return Err(anyhow!("invalid session name {session:?} (must be path-safe)"));
    }
    Ok(())
}

fn state_json(snap: &SessionSnapshot) -> Value {
    let s = &snap.summary;
    let trace: Vec<Value> = snap
        .trace
        .iter()
        .map(|r| {
            Value::object(vec![
                ("round", Value::from(r.round)),
                (
                    "placement",
                    Value::Array(r.placement.iter().map(|&c| Value::from(c)).collect()),
                ),
                ("delay_s", Value::Num(r.delay_s)),
                ("loss", Value::Num(r.loss)),
                ("live", Value::from(r.live)),
            ])
        })
        .collect();
    Value::object(vec![
        (
            "summary",
            Value::object(vec![
                ("strategy", Value::from(s.strategy.as_str())),
                ("rounds", Value::from(s.rounds)),
                // u64 seeds are stored as strings: JSON numbers are f64
                // and would corrupt SplitMix64-derived replicate seeds.
                ("seed", Value::from(s.seed.to_string())),
                ("client_count", Value::from(s.client_count)),
                ("dims", Value::from(s.dims)),
                ("backend", Value::from(s.backend.as_str())),
            ]),
        ),
        ("next_round", Value::from(snap.next_round)),
        ("phase", Value::from(snap.phase.as_str())),
        ("trace", Value::Array(trace)),
    ])
}

fn state_from_json(v: &Value) -> Result<SessionSnapshot, String> {
    let need = |field: &str| format!("state.json missing {field}");
    let s = v.get("summary").ok_or_else(|| need("summary"))?;
    let get_usize = |obj: &Value, key: &str| {
        obj.get(key).and_then(Value::as_usize).ok_or_else(|| need(key))
    };
    let summary = SpecSummary {
        strategy: s
            .get("strategy")
            .and_then(Value::as_str)
            .ok_or_else(|| need("strategy"))?
            .to_string(),
        rounds: get_usize(s, "rounds")?,
        seed: s
            .get("seed")
            .and_then(Value::as_str)
            .and_then(|t| t.parse::<u64>().ok())
            .ok_or_else(|| need("seed"))?,
        client_count: get_usize(s, "client_count")?,
        dims: get_usize(s, "dims")?,
        backend: s
            .get("backend")
            .and_then(Value::as_str)
            .ok_or_else(|| need("backend"))?
            .to_string(),
    };
    let mut trace = Vec::new();
    for row in v.get("trace").and_then(Value::as_array).ok_or_else(|| need("trace"))? {
        let placement = row
            .get("placement")
            .and_then(Value::as_array)
            .ok_or_else(|| need("trace.placement"))?
            .iter()
            .map(|c| c.as_usize().ok_or("trace.placement holds a non-integer"))
            .collect::<Result<Vec<usize>, _>>()?;
        trace.push(TraceRow {
            round: get_usize(row, "round")?,
            placement,
            // NaN serializes to JSON null, which parses back as absent.
            delay_s: row.get("delay_s").and_then(Value::as_f64).unwrap_or(f64::NAN),
            loss: row.get("loss").and_then(Value::as_f64).unwrap_or(f64::NAN),
            live: get_usize(row, "live")?,
        });
    }
    let next_round = v
        .get("next_round")
        .and_then(Value::as_usize)
        .ok_or_else(|| need("next_round"))?;
    Ok(SessionSnapshot {
        summary,
        next_round,
        phase: v
            .get("phase")
            .and_then(Value::as_str)
            .ok_or_else(|| need("phase"))?
            .to_string(),
        trace,
        // Filled from model.ckpt by the caller.
        optimizer: None,
        params: Vec::new(),
        loss: f64::NAN,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::Placement;

    fn snapshot() -> SessionSnapshot {
        SessionSnapshot {
            summary: SpecSummary {
                strategy: "pso".into(),
                rounds: 10,
                seed: 0xDEAD_BEEF_CAFE_F00D,
                client_count: 12,
                dims: 3,
                backend: "event-driven".into(),
            },
            next_round: 2,
            phase: "round(2)".into(),
            trace: vec![
                TraceRow {
                    round: 0,
                    placement: vec![4, 0, 9],
                    delay_s: 3.25,
                    loss: f64::NAN,
                    live: 12,
                },
                TraceRow {
                    round: 1,
                    placement: vec![1, 2, 3],
                    delay_s: 2.5,
                    loss: 0.75,
                    live: 11,
                },
            ],
            optimizer: Some(OptimizerState {
                name: "pso".into(),
                best: Some((Placement::new(vec![1, 2, 3]), 2.5)),
            }),
            params: vec![0.5, -1.25, 3.0],
            loss: 0.75,
        }
    }

    /// NaN fields defeat PartialEq; compare through a NaN-normalizing view.
    fn assert_snap_eq(a: &SessionSnapshot, b: &SessionSnapshot) {
        let norm = |s: &SessionSnapshot| {
            let mut s = s.clone();
            for r in &mut s.trace {
                if r.loss.is_nan() {
                    r.loss = -1.0;
                }
                if r.delay_s.is_nan() {
                    r.delay_s = -1.0;
                }
            }
            if s.loss.is_nan() {
                s.loss = -1.0;
            }
            s
        };
        assert_eq!(norm(a), norm(b));
    }

    #[test]
    fn noop_store_roundtrips() {
        let store = NoopStore::new();
        assert!(store.load("s0").unwrap().is_none());
        store.save("s0", &snapshot()).unwrap();
        assert_snap_eq(&store.load("s0").unwrap().unwrap(), &snapshot());
        assert_eq!(store.sessions().unwrap(), vec!["s0".to_string()]);
        store.remove("s0").unwrap();
        assert!(store.load("s0").unwrap().is_none());
    }

    #[test]
    fn dir_store_roundtrips_through_files() {
        let root = std::env::temp_dir().join("repro_store_roundtrip");
        let _ = std::fs::remove_dir_all(&root);
        let store = DirStore::open(&root).unwrap();
        assert!(store.load("alpha").unwrap().is_none());
        store.save("alpha", &snapshot()).unwrap();
        store.save("beta", &snapshot()).unwrap();
        // A second handle (fresh process emulation) sees the same state.
        let reopened = DirStore::open(&root).unwrap();
        assert_snap_eq(&reopened.load("alpha").unwrap().unwrap(), &snapshot());
        assert_eq!(
            reopened.sessions().unwrap(),
            vec!["alpha".to_string(), "beta".to_string()]
        );
        // The checkpoint half is a standard runtime::checkpoint file.
        let (params, meta) =
            checkpoint::load(&root.join("alpha").join("model.ckpt")).unwrap();
        assert_eq!(params, snapshot().params);
        assert_eq!(meta.round, 2);
        assert_eq!(meta.optimizer, snapshot().optimizer);
        reopened.remove("alpha").unwrap();
        assert!(reopened.load("alpha").unwrap().is_none());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn seed_survives_beyond_f64_precision() {
        // 0xDEAD_BEEF_CAFE_F00D > 2^53: a float round-trip would corrupt it.
        let root = std::env::temp_dir().join("repro_store_seed");
        let _ = std::fs::remove_dir_all(&root);
        let store = DirStore::open(&root).unwrap();
        store.save("s", &snapshot()).unwrap();
        let back = store.load("s").unwrap().unwrap();
        assert_eq!(back.summary.seed, 0xDEAD_BEEF_CAFE_F00D);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rejects_path_escaping_names() {
        let store = NoopStore::new();
        for bad in ["", "../x", "a/b", "a\\b"] {
            assert!(store.save(bad, &snapshot()).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn empty_param_sessions_are_legal() {
        // Env-backed sessions have no model; 0-param checkpoints are valid.
        let root = std::env::temp_dir().join("repro_store_noparams");
        let _ = std::fs::remove_dir_all(&root);
        let store = DirStore::open(&root).unwrap();
        let mut snap = snapshot();
        snap.params.clear();
        snap.optimizer = None;
        store.save("env", &snap).unwrap();
        let back = store.load("env").unwrap().unwrap();
        assert!(back.params.is_empty());
        assert_eq!(back.optimizer, None);
        let _ = std::fs::remove_dir_all(&root);
    }
}
