//! The coordinator service: accepts session submissions, multiplexes
//! them over one shared [`Broker`] and a [`TrialScheduler`] worker pool,
//! persists every session through the configured [`Store`], and feeds
//! every event through the configured [`Recorder`].
//!
//! `submit` is cheap (validation only); [`CoordinatorService::drain`]
//! does the work: it loads each submitted session's snapshot (resuming
//! any that a previous — possibly killed — service incarnation left
//! mid-flight), builds one [`SessionRunner`] per session, runs them
//! concurrently, then emits metric rows in submission order so the CSV
//! sink is byte-deterministic for any thread count.

use super::backend::LiveBackend;
use super::machine::Phase;
use super::metrics::Recorder;
use super::session::{SessionKind, SessionOutcome, SessionRunner, SessionSpec};
use super::storage::Store;
use super::metrics::MetricRow;
use crate::broker::Broker;
use crate::exp::TrialScheduler;
use crate::fault::{BackoffPolicy, BrokerFaults, FaultPlan, FaultyStore, RetryStore};
use crate::log_warn;
use crate::obs::defs as obs;
use crate::runtime::ModelRuntime;
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex};

/// Shared state of the incremental row flush: each completing session
/// deposits its rows into its submission-order slot; whoever deposits
/// then drains the contiguous completed prefix into the recorder. The
/// frontier (`next`) only moves forward, so rows always hit the sink
/// in submission order — the final file is byte-identical to the old
/// record-everything-after-drain behavior, but a killed coordinator
/// now keeps every fully-completed session's paper trail on disk.
struct FlushState {
    slots: Vec<Option<Vec<MetricRow>>>,
    next: usize,
    error: Option<std::io::Error>,
}

/// Service-level knobs (per-session knobs live on [`SessionSpec`]).
/// The default is zero threads (one worker per core) and no round
/// limit.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServiceConfig {
    /// Scheduler worker threads (0 = one per available core).
    pub threads: usize,
    /// Max rounds each drained session may execute in this incarnation
    /// (`None` = run to completion). A paused session persists as
    /// resumable mid-flight state — the test hook for killing a
    /// coordinator between rounds.
    pub round_limit: Option<usize>,
    /// Retry policy for store saves/loads (the [`RetryStore`] layer the
    /// service wraps around whatever store it was given).
    pub backoff: BackoffPolicy,
}

/// A long-running multi-session coordinator.
pub struct CoordinatorService {
    cfg: ServiceConfig,
    store: Arc<dyn Store>,
    recorder: Box<dyn Recorder>,
    broker: Broker,
    runtime: Option<Arc<ModelRuntime>>,
    pending: Vec<SessionSpec>,
    faults: Option<Arc<FaultPlan>>,
}

impl CoordinatorService {
    pub fn new(
        cfg: ServiceConfig,
        store: Arc<dyn Store>,
        recorder: Box<dyn Recorder>,
    ) -> CoordinatorService {
        CoordinatorService {
            cfg,
            store,
            recorder,
            broker: Broker::new(),
            runtime: None,
            pending: Vec::new(),
            faults: None,
        }
    }

    /// Attach the PJRT model runtime live sessions train against.
    pub fn with_runtime(mut self, runtime: Arc<ModelRuntime>) -> CoordinatorService {
        self.runtime = Some(runtime);
        self
    }

    /// Attach a deterministic fault plan: the shared broker gets a
    /// [`BrokerFaults`] interceptor, the store gets a [`FaultyStore`]
    /// layer under the retry layer, and every drained runner executes
    /// its rounds through a `FaultyBackend` wrapper. An empty plan is
    /// provably neutral (see `tests/fault_injection.rs`).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> CoordinatorService {
        self.broker.set_interceptor(Some(Arc::new(BrokerFaults::new(plan.clone()))));
        self.faults = Some(plan);
        self
    }

    /// Sessions submitted and not yet drained.
    pub fn pending_sessions(&self) -> usize {
        self.pending.len()
    }

    /// Validate and queue a session. Live sessions require a runtime;
    /// names must be unique within the queue (they are storage keys).
    pub fn submit(&mut self, spec: SessionSpec) -> Result<()> {
        spec.validate()?;
        if self.pending.iter().any(|s| s.name == spec.name) {
            return Err(anyhow!("session {:?} already submitted", spec.name));
        }
        if matches!(spec.kind, SessionKind::Live { .. }) && self.runtime.is_none() {
            return Err(anyhow!(
                "session {:?} is live but the service has no model runtime attached",
                spec.name
            ));
        }
        self.pending.push(spec);
        Ok(())
    }

    /// Run every queued session to its stopping point and return the
    /// outcomes in submission order. Sessions run concurrently on the
    /// scheduler pool; each one persists after every completed round,
    /// and any session with a stored snapshot resumes from it instead
    /// of re-running completed rounds.
    pub fn drain(&mut self) -> Result<Vec<SessionOutcome>> {
        let specs: Vec<SessionSpec> = self.pending.drain(..).collect();
        // Layer the store: capped-backoff retries outermost, injected
        // faults (when a plan is attached) between the retries and the
        // real store — so injected IO errors exercise the same retry
        // path real flakiness would.
        let store: Arc<dyn Store> = match &self.faults {
            Some(plan) => Arc::new(RetryStore::new(
                Arc::new(FaultyStore::new(self.store.clone(), plan.clone())),
                self.cfg.backoff,
            )),
            None => Arc::new(RetryStore::new(self.store.clone(), self.cfg.backoff)),
        };
        let mut runners = Vec::with_capacity(specs.len());
        for spec in specs {
            let started = std::time::Instant::now();
            // Hardened: a snapshot load that still fails after retries
            // degrades this session to a fresh run (deterministic specs
            // reproduce the same rounds) instead of aborting the whole
            // drain.
            let snapshot = match store.load(&spec.name) {
                Ok(snap) => snap,
                Err(e) => {
                    log_warn!(
                        "service",
                        "session {}: snapshot load failed ({e:#}) — starting fresh",
                        spec.name
                    );
                    None
                }
            };
            crate::obs::defs::STORE_LOAD.observe(started.elapsed().as_secs_f64());
            let runner = match &spec.kind {
                SessionKind::Env { .. } => SessionRunner::new_env(spec, snapshot)?,
                SessionKind::Live { deploy, time_scale } => {
                    let runtime = self
                        .runtime
                        .clone()
                        .ok_or_else(|| anyhow!("live session without a runtime"))?;
                    let backend = LiveBackend::launch(
                        deploy,
                        &spec.name,
                        runtime,
                        &self.broker,
                        *time_scale,
                    )?;
                    SessionRunner::new_live(spec, backend, snapshot)?
                }
            };
            let runner = match &self.faults {
                Some(plan) => runner.with_faults(plan.clone()),
                None => runner,
            };
            runners.push(runner);
        }
        let limit = self.cfg.round_limit;
        let n = runners.len();
        // (name, strategy) per slot — needed to synthesize outcomes for
        // quarantined sessions after their runners were consumed.
        let labels: Vec<(String, String)> = runners
            .iter()
            .map(|r| (r.name().to_string(), r.strategy().to_string()))
            .collect();
        let flush = Mutex::new(FlushState {
            slots: (0..n).map(|_| None).collect(),
            next: 0,
            error: None,
        });
        let recorder = Mutex::new(&mut self.recorder);
        let scheduler = TrialScheduler::new(self.cfg.threads);
        let results = scheduler.run_consuming_catching(runners, |i, runner: SessionRunner| {
            let outcome = match runner.run(store.as_ref(), limit) {
                Ok(outcome) => outcome,
                Err(e) => {
                    // Hardened: one session's hard error (e.g. a persist
                    // that failed every retry) becomes a Failed outcome
                    // with its reason on the paper trail — not a
                    // drain-wide abort that loses every other session.
                    let (name, strategy) = labels[i].clone();
                    log_warn!("service", "session {name}: aborted ({e:#})");
                    SessionOutcome {
                        name: name.clone(),
                        strategy: strategy.clone(),
                        phase: Phase::Failed,
                        trace: Vec::new(),
                        rows: vec![MetricRow {
                            session: name,
                            seq: 0,
                            kind: "phase",
                            round: None,
                            strategy,
                            placement: Vec::new(),
                            delay_s: None,
                            detail: format!("aborted: {e:#}"),
                        }],
                        best: None,
                        resumed_from: None,
                    }
                }
            };
            let rows = outcome.rows.clone();
            // Deposit this session's rows, then flush the contiguous
            // completed prefix at each session-completion boundary
            // (lock order: flush state, then recorder — everywhere).
            let mut state = flush.lock().expect("flush state lock");
            state.slots[i] = Some(rows);
            let mut rec = recorder.lock().expect("recorder lock");
            while state.next < n && state.slots[state.next].is_some() {
                let rows = state.slots[state.next].take().expect("slot just checked");
                state.next += 1;
                if state.error.is_some() {
                    continue; // sink already broken: drop quietly, surface below
                }
                let io = rows
                    .iter()
                    .try_for_each(|row| rec.record(row))
                    .and_then(|()| rec.flush());
                if let Err(e) = io {
                    state.error = Some(e);
                }
            }
            outcome
        });
        drop(recorder);
        let mut state = flush.into_inner().expect("flush state lock");
        // Quarantine: a panicked worker never deposited its rows, so the
        // flush frontier stalled at its slot. Synthesize the quarantine
        // row into that slot, then drain everything the stall parked.
        let mut quarantine_rows: Vec<Option<MetricRow>> = (0..n).map(|_| None).collect();
        for (i, result) in results.iter().enumerate() {
            if let Err(panic) = result {
                obs::SERVICE_SESSIONS_QUARANTINED.inc();
                let (name, strategy) = &labels[i];
                log_warn!(
                    "service",
                    "session {name}: worker panicked — quarantined ({})",
                    panic.message
                );
                let row = MetricRow {
                    session: name.clone(),
                    seq: 0,
                    kind: "phase",
                    round: None,
                    strategy: strategy.clone(),
                    placement: Vec::new(),
                    delay_s: None,
                    detail: format!("quarantined: {}", panic.message),
                };
                state.slots[i] = Some(vec![row.clone()]);
                quarantine_rows[i] = Some(row);
            }
        }
        while state.next < n {
            let Some(rows) = state.slots[state.next].take() else { break };
            state.next += 1;
            if state.error.is_some() {
                continue;
            }
            let io = rows
                .iter()
                .try_for_each(|row| self.recorder.record(row))
                .and_then(|()| self.recorder.flush());
            if let Err(e) = io {
                state.error = Some(e);
            }
        }
        let sink_error = state.error;
        let mut outcomes = Vec::with_capacity(results.len());
        for (i, result) in results.into_iter().enumerate() {
            match result {
                Ok(outcome) => outcomes.push(outcome),
                Err(_) => {
                    let (name, strategy) = labels[i].clone();
                    let row = quarantine_rows[i].take().expect("quarantine row just built");
                    outcomes.push(SessionOutcome {
                        name,
                        strategy,
                        phase: Phase::Failed,
                        trace: Vec::new(),
                        rows: vec![row],
                        best: None,
                        resumed_from: None,
                    });
                }
            }
        }
        if let Some(e) = sink_error {
            return Err(e.into());
        }
        Ok(outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::super::machine::Phase;
    use super::super::metrics::MetricRow;
    use super::super::storage::NoopStore;
    use super::*;
    use crate::configio::SimScenario;
    use std::sync::Mutex;

    /// Captures rows into shared memory so tests can inspect the feed.
    struct CaptureRecorder(Arc<Mutex<Vec<MetricRow>>>);

    impl Recorder for CaptureRecorder {
        fn name(&self) -> &'static str {
            "capture"
        }

        fn record(&mut self, row: &MetricRow) -> std::io::Result<()> {
            self.0.lock().unwrap().push(row.clone());
            Ok(())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn tiny_spec(name: &str, strategy: &str) -> SessionSpec {
        let mut sim = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        sim.pso.particles = 3;
        SessionSpec::env(name, strategy, 4, sim, "analytic")
    }

    fn service(threads: usize) -> (CoordinatorService, Arc<Mutex<Vec<MetricRow>>>) {
        let rows = Arc::new(Mutex::new(Vec::new()));
        let cfg = ServiceConfig { threads, ..ServiceConfig::default() };
        let recorder = Box::new(CaptureRecorder(rows.clone()));
        (CoordinatorService::new(cfg, Arc::new(NoopStore::new()), recorder), rows)
    }

    #[test]
    fn drain_runs_queued_sessions_and_feeds_the_recorder_in_order() {
        let (mut svc, rows) = service(2);
        svc.submit(tiny_spec("alpha", "pso")).unwrap();
        svc.submit(tiny_spec("beta", "round-robin")).unwrap();
        assert_eq!(svc.pending_sessions(), 2);
        let outcomes = svc.drain().unwrap();
        assert_eq!(svc.pending_sessions(), 0);
        assert_eq!(outcomes.len(), 2);
        for out in &outcomes {
            assert_eq!(out.phase, Phase::Finished);
            assert_eq!(out.trace.len(), 4);
        }
        // Submission order, regardless of which worker finished first.
        let rows = rows.lock().unwrap();
        let sessions: Vec<&str> = rows.iter().map(|r| r.session.as_str()).collect();
        let split = sessions.iter().position(|&s| s == "beta").unwrap();
        assert!(sessions[..split].iter().all(|&s| s == "alpha"));
        assert!(sessions[split..].iter().all(|&s| s == "beta"));
        // An empty drain is a no-op.
        assert!(svc.drain().unwrap().is_empty());
    }

    #[test]
    fn thread_count_does_not_change_session_traces() {
        let run = |threads: usize| {
            let (mut svc, _) = service(threads);
            svc.submit(tiny_spec("a", "pso")).unwrap();
            svc.submit(tiny_spec("b", "ga")).unwrap();
            svc.drain().unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.name, p.name);
            let sd: Vec<u64> = s.trace.iter().map(|r| r.delay_s.to_bits()).collect();
            let pd: Vec<u64> = p.trace.iter().map(|r| r.delay_s.to_bits()).collect();
            assert_eq!(sd, pd, "session {} must not depend on thread count", s.name);
        }
    }

    /// Tags every row with the flush count at record time, so tests can
    /// prove rows hit the sink incrementally (at session boundaries),
    /// not in one post-drain burst.
    struct FlushTrackingRecorder {
        rows: Arc<Mutex<Vec<(String, usize)>>>,
        flushes: Arc<Mutex<usize>>,
    }

    impl Recorder for FlushTrackingRecorder {
        fn name(&self) -> &'static str {
            "flush-tracking"
        }

        fn record(&mut self, row: &MetricRow) -> std::io::Result<()> {
            let at = *self.flushes.lock().unwrap();
            self.rows.lock().unwrap().push((row.session.clone(), at));
            Ok(())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            *self.flushes.lock().unwrap() += 1;
            Ok(())
        }
    }

    #[test]
    fn rows_flush_incrementally_at_session_boundaries() {
        let rows = Arc::new(Mutex::new(Vec::new()));
        let flushes = Arc::new(Mutex::new(0usize));
        for threads in [1, 2] {
            rows.lock().unwrap().clear();
            *flushes.lock().unwrap() = 0;
            let cfg = ServiceConfig { threads, ..ServiceConfig::default() };
            let recorder = Box::new(FlushTrackingRecorder {
                rows: rows.clone(),
                flushes: flushes.clone(),
            });
            let mut svc =
                CoordinatorService::new(cfg, Arc::new(NoopStore::new()), recorder);
            svc.submit(tiny_spec("alpha", "pso")).unwrap();
            svc.submit(tiny_spec("beta", "round-robin")).unwrap();
            svc.drain().unwrap();
            // One flush per completed session (a killed serve would
            // keep everything already flushed).
            assert_eq!(*flushes.lock().unwrap(), 2, "threads={threads}");
            // alpha was recorded *and flushed* before any beta row was
            // recorded — the boundary a kill test relies on.
            let rows = rows.lock().unwrap();
            assert!(rows.iter().all(|(s, at)| match s.as_str() {
                "alpha" => *at == 0,
                _ => *at >= 1,
            }));
        }
    }

    /// `Write` handle over a shared buffer, so a test can read back what
    /// a consumed `CsvRecorder` wrote.
    #[derive(Clone)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl std::io::Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn incremental_csv_bytes_match_post_hoc_recording() {
        use super::super::metrics::CsvRecorder;
        // Drain with the incremental-flush CSV sink...
        let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
        let cfg = ServiceConfig { threads: 2, ..ServiceConfig::default() };
        let recorder = Box::new(CsvRecorder::new(buf.clone()).unwrap());
        let mut svc = CoordinatorService::new(cfg, Arc::new(NoopStore::new()), recorder);
        svc.submit(tiny_spec("alpha", "pso")).unwrap();
        svc.submit(tiny_spec("beta", "ga")).unwrap();
        let outcomes = svc.drain().unwrap();
        // ...and rebuild the legacy everything-after-drain bytes from
        // the outcomes. They must be identical.
        let reference = SharedBuf(Arc::new(Mutex::new(Vec::new())));
        let mut rec = CsvRecorder::new(reference.clone()).unwrap();
        for outcome in &outcomes {
            for row in &outcome.rows {
                rec.record(row).unwrap();
            }
        }
        rec.flush().unwrap();
        let got = buf.0.lock().unwrap().clone();
        let want = reference.0.lock().unwrap().clone();
        assert!(!got.is_empty());
        assert_eq!(got, want, "incremental flush must not change the bytes");
    }

    #[test]
    fn a_panicking_session_is_quarantined_and_the_rest_complete() {
        use crate::fault::{FaultPlan, RoundFaultCfg};
        let plan = FaultPlan {
            rounds: RoundFaultCfg {
                panic_at: vec![("alpha".to_string(), 1)],
                ..RoundFaultCfg::default()
            },
            ..FaultPlan::empty()
        };
        for threads in [1, 2] {
            let (svc, rows) = service(threads);
            let mut svc = svc.with_faults(Arc::new(plan.clone()));
            svc.submit(tiny_spec("alpha", "pso")).unwrap();
            svc.submit(tiny_spec("beta", "round-robin")).unwrap();
            let outcomes = svc.drain().unwrap();
            assert_eq!(outcomes.len(), 2);
            assert_eq!(outcomes[0].name, "alpha");
            assert_eq!(outcomes[0].phase, Phase::Failed, "threads={threads}");
            assert!(outcomes[0].trace.is_empty());
            assert_eq!(outcomes[0].rows.len(), 1);
            assert!(
                outcomes[0].rows[0].detail.starts_with("quarantined: injected worker panic"),
                "{}",
                outcomes[0].rows[0].detail
            );
            // The other session is untouched by the poisoned one.
            assert_eq!(outcomes[1].phase, Phase::Finished, "threads={threads}");
            assert_eq!(outcomes[1].trace.len(), 4);
            // The recorder still got every row, in submission order —
            // the quarantine row un-stalls the flush frontier.
            let rows = rows.lock().unwrap();
            let sessions: Vec<&str> = rows.iter().map(|r| r.session.as_str()).collect();
            let split = sessions.iter().position(|&s| s == "beta").unwrap();
            assert_eq!(split, 1, "alpha contributes exactly its quarantine row");
            assert!(sessions[split..].iter().all(|&s| s == "beta"));
        }
    }

    #[test]
    fn submit_rejects_duplicates_bad_specs_and_unbacked_live_sessions() {
        let (mut svc, _) = service(1);
        svc.submit(tiny_spec("dup", "pso")).unwrap();
        let err = svc.submit(tiny_spec("dup", "ga")).unwrap_err().to_string();
        assert!(err.contains("already submitted"), "{err}");
        let mut bad = tiny_spec("zero", "pso");
        bad.rounds = 0;
        assert!(svc.submit(bad).is_err());
        let live = SessionSpec::live(
            "live0",
            "pso",
            2,
            crate::configio::DeployScenario::paper_docker(),
            1.0,
        );
        let err = svc.submit(live).unwrap_err().to_string();
        assert!(err.contains("no model runtime"), "{err}");
    }
}
