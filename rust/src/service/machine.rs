//! The per-session coordinator state machine:
//!
//! ```text
//! Standby → Rendezvous → Round(0) → … → Round(R-1) → Finishing → Finished
//!                │            │                                      │
//!                └─ timeout ──┴─ retry budget exhausted ──────────► Failed
//! ```
//!
//! The machine is pure bookkeeping — no threads, no wall clock, no I/O.
//! Time is *virtual*: the session runner advances it by the measured
//! round delays (and by explicit waits), so every transition — including
//! heartbeat-driven liveness and the timeout/retry edges — is
//! deterministic and unit-testable. Each phase edge carries a retry
//! budget; exhausting it on any edge is the only path into [`Phase::Failed`].

use std::fmt;

/// Session lifecycle phase. `Round(k)` means round `k` is in flight
/// (rounds `0..k` completed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Created, not yet submitted.
    Standby,
    /// Waiting for the client quorum to announce itself.
    Rendezvous,
    /// Executing FL round `k`.
    Round(usize),
    /// All rounds done; final persistence/flush in progress.
    Finishing,
    /// Terminal: every round completed and state flushed.
    Finished,
    /// Terminal: a retry budget was exhausted.
    Failed,
}

impl Phase {
    /// Whether the session can make no further progress.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Phase::Finished | Phase::Failed)
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Phase::Standby => write!(f, "standby"),
            Phase::Rendezvous => write!(f, "rendezvous"),
            Phase::Round(k) => write!(f, "round({k})"),
            Phase::Finishing => write!(f, "finishing"),
            Phase::Finished => write!(f, "finished"),
            Phase::Failed => write!(f, "failed"),
        }
    }
}

/// Machine parameters. All durations are virtual seconds.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    /// FL rounds the session must complete.
    pub rounds: usize,
    /// Population size (heartbeat table width).
    pub client_count: usize,
    /// Minimum live clients to start a round (the aggregator slot count:
    /// below it no valid placement exists).
    pub quorum: usize,
    /// Retries allowed on each edge before the machine fails.
    pub retry_budget: usize,
    /// Max virtual time in Rendezvous before a retry fires.
    pub rendezvous_timeout: f64,
    /// Max virtual time a round may take before a retry fires.
    pub round_timeout: f64,
    /// A client whose last heartbeat is older than this is dead.
    pub heartbeat_grace: f64,
}

impl MachineConfig {
    /// Defaults sized for service sessions: generous virtual timeouts
    /// (rounds advance time by their measured delay, so these only trip
    /// on genuinely wedged sessions) and a grace window covering one
    /// slow round plus slack.
    pub fn for_session(rounds: usize, client_count: usize, quorum: usize) -> MachineConfig {
        MachineConfig {
            rounds,
            client_count,
            quorum,
            retry_budget: 2,
            rendezvous_timeout: 300.0,
            round_timeout: 600.0,
            heartbeat_grace: 900.0,
        }
    }

    /// Reject inconsistent parameters with an actionable message.
    pub fn validate(&self) -> Result<(), String> {
        if self.rounds == 0 {
            return Err("machine: rounds must be >= 1".into());
        }
        if self.quorum == 0 || self.client_count < self.quorum {
            return Err(format!(
                "machine: need 1 <= quorum <= client_count, got quorum {} over {} clients",
                self.quorum, self.client_count
            ));
        }
        for (name, v) in [
            ("rendezvous_timeout", self.rendezvous_timeout),
            ("round_timeout", self.round_timeout),
            ("heartbeat_grace", self.heartbeat_grace),
        ] {
            if v <= 0.0 || !v.is_finite() {
                return Err(format!("machine: {name} must be > 0, got {v}"));
            }
        }
        Ok(())
    }
}

/// One recorded edge of the machine (fed to the metrics recorder).
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    pub from: Phase,
    pub to: Phase,
    /// Virtual time the edge fired at.
    pub at: f64,
    pub reason: String,
}

/// The session state machine. Drive it with [`SessionMachine::submit`],
/// heartbeats, round outcomes and [`SessionMachine::tick`]; read
/// [`SessionMachine::phase`] and the transition log back.
#[derive(Debug)]
pub struct SessionMachine {
    cfg: MachineConfig,
    phase: Phase,
    /// Virtual now (seconds since submission).
    now: f64,
    /// When the current phase was entered.
    phase_entered: f64,
    /// Retries consumed on the current edge (reset on success).
    retries: usize,
    /// First round to execute after Rendezvous (>0 on resume).
    start_round: usize,
    /// Last heartbeat per client (−∞ = never seen).
    last_beat: Vec<f64>,
    transitions: Vec<Transition>,
}

impl SessionMachine {
    pub fn new(cfg: MachineConfig) -> Result<SessionMachine, String> {
        cfg.validate()?;
        let client_count = cfg.client_count;
        Ok(SessionMachine {
            cfg,
            phase: Phase::Standby,
            now: 0.0,
            phase_entered: 0.0,
            retries: 0,
            start_round: 0,
            last_beat: vec![f64::NEG_INFINITY; client_count],
            transitions: Vec::new(),
        })
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn config(&self) -> &MachineConfig {
        &self.cfg
    }

    /// The full transition log, oldest first.
    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    fn goto(&mut self, to: Phase, reason: impl Into<String>) {
        crate::obs::defs::SERVICE_PHASE_TRANSITIONS.inc();
        match to {
            Phase::Finished => crate::obs::defs::SERVICE_SESSIONS_FINISHED.inc(),
            Phase::Failed => crate::obs::defs::SERVICE_SESSIONS_FAILED.inc(),
            _ => {}
        }
        self.transitions.push(Transition {
            from: self.phase,
            to,
            at: self.now,
            reason: reason.into(),
        });
        self.phase = to;
        self.phase_entered = self.now;
    }

    /// Advance virtual time (a measured delay or an explicit wait).
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "time cannot run backwards (dt = {dt})");
        self.now += dt;
    }

    /// Record a heartbeat from `client` at virtual now.
    pub fn beat(&mut self, client: usize) {
        self.last_beat[client] = self.now;
    }

    /// Record heartbeats for every client whose mask entry is true.
    pub fn beat_active(&mut self, active: &[bool]) {
        for (i, &on) in active.iter().enumerate().take(self.last_beat.len()) {
            if on {
                self.last_beat[i] = self.now;
            }
        }
    }

    /// Clients whose last heartbeat is within the grace window.
    pub fn live_clients(&self) -> usize {
        self.last_beat
            .iter()
            .filter(|&&t| self.now - t <= self.cfg.heartbeat_grace)
            .count()
    }

    /// Clients seen at least once whose last heartbeat has aged out of
    /// the grace window — the "missed heartbeat" population the obs
    /// counter tracks (never-seen clients are absentees, not misses).
    pub fn stale_clients(&self) -> usize {
        self.last_beat
            .iter()
            .filter(|&&t| t.is_finite() && self.now - t > self.cfg.heartbeat_grace)
            .count()
    }

    /// Whether the live population can still host every aggregator slot.
    pub fn has_quorum(&self) -> bool {
        self.live_clients() >= self.cfg.quorum
    }

    /// Standby → Rendezvous. Errors if the session was already submitted.
    pub fn submit(&mut self) -> Result<(), String> {
        match self.phase {
            Phase::Standby => {
                self.goto(Phase::Rendezvous, "submitted");
                Ok(())
            }
            p => Err(format!("submit: session already in phase {p}")),
        }
    }

    /// Fast-forward a resumed session: rounds `0..round` were completed
    /// by a previous incarnation and restored from storage. Only legal
    /// before submission.
    pub fn resume_at(&mut self, round: usize) -> Result<(), String> {
        if self.phase != Phase::Standby {
            return Err(format!("resume_at: session already in phase {}", self.phase));
        }
        if round > self.cfg.rounds {
            return Err(format!(
                "resume_at: round {round} past the session's {} rounds",
                self.cfg.rounds
            ));
        }
        self.start_round = round;
        Ok(())
    }

    /// Rendezvous → Round(start): the quorum has announced itself.
    pub fn rendezvous_complete(&mut self) -> Result<(), String> {
        match self.phase {
            Phase::Rendezvous => {
                let live = self.live_clients();
                if live < self.cfg.quorum {
                    return Err(format!(
                        "rendezvous_complete: only {live}/{} live clients",
                        self.cfg.quorum
                    ));
                }
                self.retries = 0;
                if self.start_round >= self.cfg.rounds {
                    // A fully-completed session restored from storage.
                    self.goto(Phase::Finishing, "resume: all rounds already completed");
                } else if self.start_round > 0 {
                    let k = self.start_round;
                    self.goto(Phase::Round(k), format!("resume: rounds 0..{k} restored"));
                } else {
                    self.goto(Phase::Round(0), format!("rendezvous complete ({live} live)"));
                }
                Ok(())
            }
            p => Err(format!("rendezvous_complete: in phase {p}")),
        }
    }

    /// Round(k) completed in `delay` virtual seconds: advance time, reset
    /// the retry counter and move to Round(k+1) or Finishing.
    pub fn round_completed(&mut self, delay: f64) -> Result<(), String> {
        match self.phase {
            Phase::Round(k) => {
                self.advance(delay.max(0.0));
                self.retries = 0;
                let next = k + 1;
                if next >= self.cfg.rounds {
                    self.goto(Phase::Finishing, format!("round {k} completed (last)"));
                } else {
                    self.goto(Phase::Round(next), format!("round {k} completed"));
                }
                Ok(())
            }
            p => Err(format!("round_completed: in phase {p}")),
        }
    }

    /// The in-flight round failed (backend error or lost quorum). Spends
    /// one retry; exhausting the budget fails the session. Returns the
    /// phase after the edge.
    pub fn round_failed(&mut self, reason: &str) -> Result<Phase, String> {
        match self.phase {
            Phase::Round(k) => {
                self.retries += 1;
                crate::obs::defs::SERVICE_RETRIES.inc();
                let budget = self.cfg.retry_budget;
                if self.retries > budget {
                    let why = format!("round {k}: {reason} (retry budget {budget} exhausted)");
                    self.goto(Phase::Failed, why);
                } else {
                    let why = format!("round {k}: {reason} (retry {}/{budget})", self.retries);
                    self.goto(Phase::Round(k), why);
                }
                Ok(self.phase)
            }
            p => Err(format!("round_failed: in phase {p}")),
        }
    }

    /// Check the current phase's timeout against virtual now; fires the
    /// retry edge (or fails) when exceeded. Returns the phase after the
    /// check. No-op in terminal phases and Standby/Finishing.
    pub fn tick(&mut self) -> Phase {
        let elapsed = self.now - self.phase_entered;
        match self.phase {
            Phase::Rendezvous if elapsed > self.cfg.rendezvous_timeout => {
                self.retries += 1;
                crate::obs::defs::SERVICE_RETRIES.inc();
                let budget = self.cfg.retry_budget;
                if self.retries > budget {
                    let why = format!("rendezvous timeout after {elapsed:.1}s (budget exhausted)");
                    self.goto(Phase::Failed, why);
                } else {
                    let why = format!("rendezvous timeout (retry {}/{budget})", self.retries);
                    self.goto(Phase::Rendezvous, why);
                }
            }
            Phase::Round(k) if elapsed > self.cfg.round_timeout => {
                // Reuse the round retry edge for timeouts.
                let _ = self.round_failed(&format!("timeout after {elapsed:.1}s in round {k}"));
            }
            _ => {}
        }
        self.phase
    }

    /// Finishing → Finished: final state flushed.
    pub fn drained(&mut self) -> Result<(), String> {
        match self.phase {
            Phase::Finishing => {
                self.goto(Phase::Finished, "drained");
                Ok(())
            }
            p => Err(format!("drained: in phase {p}")),
        }
    }

    /// Force the session into Failed from any non-terminal phase.
    pub fn fail(&mut self, reason: &str) {
        if !self.phase.is_terminal() {
            self.goto(Phase::Failed, reason.to_string());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine(rounds: usize, clients: usize, quorum: usize) -> SessionMachine {
        SessionMachine::new(MachineConfig::for_session(rounds, clients, quorum)).unwrap()
    }

    fn all_beat(m: &mut SessionMachine, n: usize) {
        m.beat_active(&vec![true; n]);
    }

    #[test]
    fn happy_path_walks_every_phase() {
        let mut m = machine(2, 6, 3);
        assert_eq!(m.phase(), Phase::Standby);
        m.submit().unwrap();
        assert_eq!(m.phase(), Phase::Rendezvous);
        all_beat(&mut m, 6);
        m.rendezvous_complete().unwrap();
        assert_eq!(m.phase(), Phase::Round(0));
        m.round_completed(1.5).unwrap();
        assert_eq!(m.phase(), Phase::Round(1));
        m.round_completed(2.0).unwrap();
        assert_eq!(m.phase(), Phase::Finishing);
        m.drained().unwrap();
        assert_eq!(m.phase(), Phase::Finished);
        assert!(m.phase().is_terminal());
        assert!((m.now() - 3.5).abs() < 1e-12, "time advances by round delays");
        // Every edge was logged, in order, starting from Standby.
        let t = m.transitions();
        assert_eq!(t.first().unwrap().from, Phase::Standby);
        assert_eq!(t.last().unwrap().to, Phase::Finished);
        for w in t.windows(2) {
            assert_eq!(w[0].to, w[1].from, "transition log must chain");
        }
    }

    #[test]
    fn rendezvous_requires_quorum_and_times_out_into_failed() {
        let mut m = machine(1, 4, 3);
        m.submit().unwrap();
        // Only 2 of 4 clients ever announce themselves.
        m.beat(0);
        m.beat(1);
        assert!(!m.has_quorum());
        assert!(m.rendezvous_complete().is_err());
        // Each timeout spends one retry; budget 2 → third timeout fails.
        for expect_retry in [true, true, false] {
            m.advance(m.config().rendezvous_timeout + 1.0);
            let p = m.tick();
            if expect_retry {
                assert_eq!(p, Phase::Rendezvous);
            } else {
                assert_eq!(p, Phase::Failed);
            }
        }
        assert!(m.transitions().iter().any(|t| t.reason.contains("budget exhausted")));
    }

    #[test]
    fn round_retries_then_recovers() {
        let mut m = machine(1, 6, 3);
        m.submit().unwrap();
        all_beat(&mut m, 6);
        m.rendezvous_complete().unwrap();
        assert_eq!(m.round_failed("broker hiccup").unwrap(), Phase::Round(0));
        assert_eq!(m.round_failed("broker hiccup").unwrap(), Phase::Round(0));
        // A success resets the retry counter and finishes the session.
        m.round_completed(1.0).unwrap();
        assert_eq!(m.phase(), Phase::Finishing);
    }

    #[test]
    fn round_retry_budget_exhausts_into_failed() {
        let mut m = machine(3, 6, 3);
        m.submit().unwrap();
        all_beat(&mut m, 6);
        m.rendezvous_complete().unwrap();
        m.round_completed(1.0).unwrap();
        assert_eq!(m.phase(), Phase::Round(1));
        assert_eq!(m.round_failed("x").unwrap(), Phase::Round(1));
        assert_eq!(m.round_failed("x").unwrap(), Phase::Round(1));
        assert_eq!(m.round_failed("x").unwrap(), Phase::Failed);
        // Terminal: further events are rejected, fail() is a no-op.
        assert!(m.round_completed(1.0).is_err());
        let edges = m.transitions().len();
        m.fail("again");
        assert_eq!(m.transitions().len(), edges);
    }

    #[test]
    fn heartbeats_expire_after_the_grace_window() {
        let mut m = machine(1, 5, 2);
        m.submit().unwrap();
        all_beat(&mut m, 5);
        assert_eq!(m.live_clients(), 5);
        m.advance(m.config().heartbeat_grace + 0.1);
        assert_eq!(m.live_clients(), 0, "stale beats must expire");
        assert_eq!(m.stale_clients(), 5, "all seen clients aged out");
        m.beat(3);
        m.beat(4);
        assert_eq!(m.live_clients(), 2);
        assert_eq!(m.stale_clients(), 3);
        assert!(m.has_quorum());
    }

    #[test]
    fn never_seen_clients_are_not_stale() {
        let m = machine(1, 4, 2);
        assert_eq!(m.stale_clients(), 0, "absentees are not heartbeat misses");
    }

    #[test]
    fn round_timeout_fires_the_retry_edge() {
        let mut m = machine(1, 6, 3);
        m.submit().unwrap();
        all_beat(&mut m, 6);
        m.rendezvous_complete().unwrap();
        m.advance(m.config().round_timeout + 5.0);
        assert_eq!(m.tick(), Phase::Round(0), "first timeout retries");
        assert!(m.transitions().last().unwrap().reason.contains("timeout"));
    }

    #[test]
    fn resume_fast_forwards_to_the_stored_round() {
        let mut m = machine(5, 6, 3);
        m.resume_at(3).unwrap();
        m.submit().unwrap();
        all_beat(&mut m, 6);
        m.rendezvous_complete().unwrap();
        assert_eq!(m.phase(), Phase::Round(3));
        m.round_completed(1.0).unwrap();
        m.round_completed(1.0).unwrap();
        assert_eq!(m.phase(), Phase::Finishing);
        // A fully-completed snapshot goes straight to Finishing.
        let mut done = machine(2, 6, 3);
        done.resume_at(2).unwrap();
        done.submit().unwrap();
        all_beat(&mut done, 6);
        done.rendezvous_complete().unwrap();
        assert_eq!(done.phase(), Phase::Finishing);
        // Resuming past the configured rounds is rejected.
        let mut over = machine(2, 6, 3);
        assert!(over.resume_at(3).is_err());
        // Resuming after submission is rejected.
        let mut late = machine(2, 6, 3);
        late.submit().unwrap();
        assert!(late.resume_at(1).is_err());
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        assert!(MachineConfig::for_session(0, 6, 3).validate().is_err());
        assert!(MachineConfig::for_session(1, 2, 3).validate().is_err());
        assert!(MachineConfig::for_session(1, 6, 0).validate().is_err());
        let mut cfg = MachineConfig::for_session(1, 6, 3);
        cfg.round_timeout = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn phase_labels_are_stable() {
        // Storage and the metrics CSV both persist these labels.
        assert_eq!(Phase::Standby.to_string(), "standby");
        assert_eq!(Phase::Round(7).to_string(), "round(7)");
        assert_eq!(Phase::Finished.to_string(), "finished");
        assert_eq!(Phase::Failed.to_string(), "failed");
    }
}
