//! Coordinator-as-a-service: a persistent, multi-session coordinator
//! built from four pieces —
//!
//! * [`machine`] — the per-session state machine
//!   (`Standby → Rendezvous → Round(k) → Finishing → Finished/Failed`)
//!   over virtual time, with heartbeat liveness and timeout/retry
//!   edges;
//! * [`storage`] — pluggable persistence ([`Store`]: in-memory
//!   [`NoopStore`], file-backed [`DirStore`] layered over
//!   `runtime::checkpoint`) so a killed coordinator resumes every
//!   in-flight session from its last completed round;
//! * [`metrics`] — a [`Recorder`] sink (noop / CSV) that every phase
//!   transition, round outcome and placement score flows through;
//! * [`session`] + [`server`] — the per-session runner and the
//!   [`CoordinatorService`] that multiplexes many concurrent sessions
//!   over one shared broker and a deterministic worker pool.
//!
//! ## Phases ↔ the paper's Flag-Swap round protocol
//!
//! The paper's SDFLMQ coordinator runs rounds as a pub/sub
//! conversation: clients announce themselves, the coordinator publishes
//! each round's role arrangement (who aggregates, who trains — the
//! "flag swap"), trainers upload, aggregators merge bottom-up, and the
//! measured round delay feeds the PSO placement search. The machine
//! names each beat of that conversation:
//!
//! | phase | protocol moment |
//! |-------|-----------------|
//! | `Standby` | session registered, `FLSession` topics not yet live |
//! | `Rendezvous` | clients publishing ready on the session topics; the quorum is the aggregator slot count (below it no placement is feasible) |
//! | `Round(k)` | one Flag-Swap round: placement proposed by the session's [`Optimizer`], roles broadcast, updates merged, TPD measured and fed back |
//! | `Finishing` | all rounds done; final snapshot + metrics flush |
//! | `Finished` / `Failed` | terminal — drained cleanly, or a retry budget exhausted |
//!
//! Between `Round(k)` and `Round(k+1)` the runner persists a
//! [`SessionSnapshot`], so the service can die at any round boundary
//! and resume without re-running completed rounds (resume *replays*
//! the persisted trace through a freshly seeded optimizer, restoring
//! its RNG bit-exactly — see [`session`]).
//!
//! [`Optimizer`]: crate::placement::Optimizer

pub mod backend;
pub mod machine;
pub mod metrics;
pub mod server;
pub mod session;
pub mod storage;

pub use backend::{EnvBackend, LiveBackend, RoundBackend, RoundOutcome};
pub use machine::{MachineConfig, Phase, SessionMachine, Transition};
pub use metrics::{CsvRecorder, MetricRow, NoopRecorder, Recorder, CSV_SCHEMA};
pub use server::{CoordinatorService, ServiceConfig};
pub use session::{SessionKind, SessionOutcome, SessionRunner, SessionSpec};
pub use storage::{DirStore, NoopStore, SessionSnapshot, SpecSummary, Store, TraceRow};
