//! proplite — property-based testing harness (substrate — no `proptest`
//! offline; the python side uses hypothesis).
//!
//! Runs a property over many seeded-random cases; on failure it reports
//! the seed and case index so the exact input regenerates, then attempts
//! a bounded "shrink" by re-running with smaller size hints.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath)
//! use repro::proplite::{forall, Gen};
//! forall("sorted idempotent", 200, |g| {
//!     let mut xs = g.vec_u64(0..100, 64);
//!     xs.sort();
//!     let once = xs.clone();
//!     xs.sort();
//!     assert_eq!(xs, once);
//! });
//! ```

use crate::prng::{Pcg32, Rng};
use std::ops::Range;

/// Per-case generator handle: seeded randomness + a size hint that the
/// shrinker lowers on failure.
pub struct Gen {
    rng: Pcg32,
    /// Current size hint in `[0.0, 1.0]`; generators scale lengths by it.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Pcg32::seed_from_u64(seed),
            size,
        }
    }

    /// Uniform u64 in range.
    pub fn u64_in(&mut self, r: Range<u64>) -> u64 {
        assert!(r.start < r.end);
        r.start + self.rng.gen_range(r.end - r.start)
    }

    /// Uniform usize in range.
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.u64_in(r.start as u64..r.end as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Length scaled by the current size hint (at least 0).
    pub fn len(&mut self, max: usize) -> usize {
        let scaled = ((max as f64) * self.size).ceil() as usize;
        self.usize_in(0..scaled.max(1) + 1)
    }

    /// Vec of u64 drawn from `each`, length ≤ max_len (size-scaled).
    pub fn vec_u64(&mut self, each: Range<u64>, max_len: usize) -> Vec<u64> {
        let n = self.len(max_len);
        (0..n).map(|_| self.u64_in(each.clone())).collect()
    }

    /// Vec of f64 in [lo, hi), length ≤ max_len (size-scaled).
    pub fn vec_f64(&mut self, lo: f64, hi: f64, max_len: usize) -> Vec<f64> {
        let n = self.len(max_len);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Access the raw RNG for custom draws.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` seeded cases. Panics (with reproduction info)
/// on the first failing case, after trying smaller-sized variants of the
/// same seed to report the smallest observed failure.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    let base_seed = match std::env::var("PROPLITE_SEED") {
        Ok(s) => s.parse::<u64>().expect("PROPLITE_SEED must be u64"),
        Err(_) => 0xC0FF_EE00,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let failed = std::panic::catch_unwind(|| {
            // Quiet the default panic hook while probing.
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        });
        if failed.is_err() {
            // Shrink: retry the same seed with smaller size hints and
            // report the smallest size that still fails.
            let mut smallest = 1.0f64;
            for &size in &[0.05, 0.1, 0.25, 0.5] {
                let f = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                });
                if f.is_err() {
                    smallest = size;
                    break;
                }
            }
            panic!(
                "proplite: property {name:?} failed at case {case} \
                 (seed={seed}, smallest failing size hint={smallest}); \
                 re-run with PROPLITE_SEED={base_seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("addition commutes", 50, |g| {
            let a = g.u64_in(0..1000);
            let b = g.u64_in(0..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "proplite: property")]
    fn failing_property_reports() {
        forall("always fails eventually", 20, |g| {
            let v = g.u64_in(0..10);
            assert!(v < 9, "hit the 10% case");
        });
    }

    #[test]
    fn generators_respect_ranges() {
        forall("ranges hold", 100, |g| {
            assert!(g.u64_in(5..10) >= 5);
            assert!(g.usize_in(0..3) < 3);
            let x = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
            let v = g.vec_f64(0.0, 1.0, 16);
            assert!(v.len() <= 17);
        });
    }
}
