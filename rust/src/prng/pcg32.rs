//! PCG32 (XSH-RR 64/32) — O'Neill 2014. Small, fast, statistically solid;
//! the workhorse generator behind PSO's stochastic terms, the baselines
//! and the simulator.

use super::{Rng, SplitMix64};

const MULTIPLIER: u64 = 6_364_136_223_846_793_005;

/// PCG32 state (64-bit state + odd stream increment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create from explicit state/stream values (PCG reference `pcg32_srandom`).
    pub fn new(initstate: u64, initseq: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Derive state and stream from one 64-bit seed via SplitMix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next();
        let i = sm.next();
        Self::new(s, i)
    }

    /// Split off an independent child stream (used to give every client /
    /// particle / bench its own reproducible randomness).
    pub fn split(&mut self) -> Self {
        let s = self.next_u64();
        let i = self.next_u64();
        Self::new(s, i)
    }
}

impl Rng for Pcg32 {
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULTIPLIER).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // First outputs of the PCG reference implementation with
        // pcg32_srandom(42, 54).
        let mut rng = Pcg32::new(42, 54);
        let expected: [u32; 6] = [
            0xa15c_02b7,
            0x7b47_f409,
            0xba1d_3330,
            0x83d2_f293,
            0xbfa4_784b,
            0xcbed_606e,
        ];
        for e in expected {
            assert_eq!(rng.next_u32(), e);
        }
    }

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg32::seed_from_u64(7);
        let mut b = Pcg32::seed_from_u64(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Pcg32::seed_from_u64(9);
        let mut c1 = root.split();
        let mut c2 = root.split();
        let collisions = (0..256).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(collisions < 3);
    }
}
