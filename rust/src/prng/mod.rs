//! Deterministic pseudo-random number generation (substrate).
//!
//! The offline crate set has no `rand`, so this module provides the two
//! generators the system needs:
//!
//! * [`SplitMix64`] — seeding / state expansion (Steele et al., 2014).
//! * [`Pcg32`] — the workhorse stream generator (O'Neill, 2014), used by
//!   the PSO optimizer (`r1`, `r2` in Eq. 2 of the paper), the placement
//!   baselines, the simulator's client-attribute sampling and the
//!   synthetic dataset generator.
//!
//! Everything downstream takes an explicit generator so simulation runs,
//! tests and benches are reproducible from a single seed.

mod pcg32;
mod splitmix64;

pub use pcg32::Pcg32;
pub use splitmix64::SplitMix64;

/// Minimal RNG interface shared by both generators.
pub trait Rng {
    /// Next raw 32 bits.
    fn next_u32(&mut self) -> u32;

    /// Next raw 64 bits (two 32-bit draws by default).
    fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform float in `[0, 1)` with 24 bits of mantissa entropy.
    fn next_f64(&mut self) -> f64 {
        f64::from(self.next_u32() >> 8) / f64::from(1u32 << 24)
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift reduction
    /// (unbiased enough for simulation purposes; exact debiasing loop).
    fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Rejection-free path for powers of two.
        if n.is_power_of_two() {
            return self.next_u64() & (n - 1);
        }
        // Widening multiply with rejection to remove modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform float in `[lo, hi)`.
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (two uniform draws per sample).
    fn normal(&mut self) -> f64 {
        // 1 - u ∈ (0, 1] keeps the log argument away from zero.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal multiplier `exp(sigma · N(0,1))` — median 1, used for
    /// link jitter and speed drift. `sigma = 0` returns exactly 1.
    fn lognormal(&mut self, sigma: f64) -> f64 {
        if sigma == 0.0 {
            return 1.0;
        }
        (sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range((n - i) as u64) as usize;
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_range_bounds() {
        let mut rng = Pcg32::seed_from_u64(1);
        for n in [1u64, 2, 3, 7, 10, 1000] {
            for _ in 0..200 {
                assert!(rng.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Pcg32::seed_from_u64(2);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut rng = Pcg32::seed_from_u64(3);
        for _ in 0..1000 {
            let x = rng.uniform(5.0, 15.0);
            assert!((5.0..15.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seed_from_u64(4);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique_and_in_range() {
        let mut rng = Pcg32::seed_from_u64(5);
        let s = rng.sample_distinct(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn normal_moments_are_sane() {
        let mut rng = Pcg32::seed_from_u64(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_is_positive_with_unit_median() {
        let mut rng = Pcg32::seed_from_u64(12);
        let mut above = 0usize;
        for _ in 0..10_000 {
            let x = rng.lognormal(0.7);
            assert!(x > 0.0 && x.is_finite());
            if x > 1.0 {
                above += 1;
            }
        }
        // Median 1 ⇒ roughly half the draws land above 1.
        assert!((4_000..6_000).contains(&above), "above={above}");
        assert_eq!(rng.lognormal(0.0), 1.0);
    }

    #[test]
    fn gen_range_roughly_uniform() {
        let mut rng = Pcg32::seed_from_u64(6);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.gen_range(10) as usize] += 1;
        }
        for c in counts {
            // Each bin expects 10k; allow ±5%.
            assert!((9_500..10_500).contains(&c), "counts={counts:?}");
        }
    }
}
