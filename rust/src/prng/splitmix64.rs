//! SplitMix64 — the standard seed-expansion generator (Steele et al. 2014).
//!
//! One multiply-xorshift round per output; passes BigCrush. Used here to
//! derive independent [`super::Pcg32`] streams from a single user seed.

use super::Rng;

/// SplitMix64 state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a raw 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl Rng for SplitMix64 {
    fn next_u32(&mut self) -> u32 {
        (self.next() >> 32) as u32
    }

    fn next_u64(&mut self) -> u64 {
        self.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference outputs for seed 0 (from the public-domain C version).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next() == b.next()).count();
        assert_eq!(same, 0);
    }
}
