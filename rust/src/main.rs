//! `repro` — CLI launcher for the Flag-Swap SDFL system.
//!
//! ```text
//! repro sim        [--strategy NAME --env analytic|event-driven --depth D --width W --particles P --iterations N --seed S --out csv]
//! repro fig3       [--out-dir results]           # all six Fig-3 panels
//! repro fleet      [--scenarios builtin|DIR --filter SUBSTR --strategies a,b,c --threads N --evals N --replicates R|MIN..MAX --out csv]
//! repro compare    [--rounds N --time-scale X --strategies a,b,c --env live|analytic|event-driven --replicates R|MIN..MAX]
//! repro serve      [--scenarios builtin|DIR --strategies a,b,c --rounds N --replicates R --env E --store noop|dir --metrics csv --dynamics NAME --faults PLAN.toml]
//! repro chaos      --faults PLAN.toml [--sessions N --rounds N --strategies a,b,c --store dir --metrics csv]
//! repro ablate     --scenario NAME [--mechanisms k1,k2 --strategy pso --evals N --replicates R --threads N --out csv]
//! repro bench      --suite eval [--samples N --warmup N --batch N --threads N --out BENCH_eval.json]
//! repro e2e        [--rounds N]                  # end-to-end PSO training run
//! repro broker     [--addr 127.0.0.1:1883]       # standalone TCP broker
//! repro obs dump   [--addr HOST:PORT]            # metric snapshot (local or scraped)
//! ```
//!
//! Global observability flags (any subcommand): `--log-level LEVEL`
//! (overrides `REPRO_LOG`), `--trace-out trace.json` (Chrome
//! trace-event export, Perfetto-viewable), `--obs-dump` (print the
//! metric snapshot at exit). `repro serve --metrics-addr HOST:PORT`
//! additionally serves Prometheus text format at `GET /metrics`.

use anyhow::{anyhow, Context, Result};
use repro::configio::{Args, DynamicsSpec, SimScenario};
use repro::des::NamedScenario;
use repro::exp::{
    replicate_seed, report_cells, run_plan, ExperimentPlan, ReplicateRange, TrialScheduler,
};
use repro::placement::registry;
use repro::service::{
    CoordinatorService, CsvRecorder, DirStore, NoopRecorder, NoopStore, Phase, Recorder,
    ServiceConfig, SessionSpec, Store,
};
use repro::sim::{ascii_plot, run_live_comparison, run_sim, run_sim_with, LiveServiceOptions};
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse_env().map_err(|e| anyhow!(e))?;
    init_observability(&args)?;
    let result = match args.subcommand.as_deref() {
        Some("sim") => cmd_sim(&args),
        Some("fig3") => cmd_fig3(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("compare") => cmd_compare(&args),
        Some("serve") => cmd_serve(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("bench") => cmd_bench(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("broker") => cmd_broker(&args),
        Some("worker") => cmd_worker(&args),
        Some("obs") => cmd_obs(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            eprintln!(
                "usage: repro <sim|fig3|fleet|compare|serve|chaos|ablate|bench|e2e|broker> [flags]\n\
                 \n\
                 sim      one placement simulation (Fig-3 style); --strategy NAME --env analytic|event-driven\n\
                 fig3     regenerate all six Fig-3 panels to CSV\n\
                 fleet    scenario × strategy × replicate matrix on the discrete-event simulator;\n\
                 \x20        --scenarios builtin|DIR --filter SUBSTR --strategies a,b,c\n\
                 \x20        --threads N --evals N --replicates R|MIN..MAX --out csv\n\
                 \x20        (replicates report mean ± 95% CI, a paired sign-test matrix and\n\
                 \x20        Wilcoxon effect sizes; MIN..MAX adapts the count per scenario,\n\
                 \x20        stopping once the leader's CI separates from every rival)\n\
                 compare  strategy comparison; --strategies a,b,c\n\
                 \x20        --env live (default): the Fig-4 deployment testbed through the\n\
                 \x20        coordinator service — --replicates R runs R independently seeded\n\
                 \x20        live sessions per strategy (--threads/--store/--store-dir/\n\
                 \x20        --metrics/--dynamics apply, see `repro serve`)\n\
                 \x20        --env analytic|event-driven: sim-tier, supports --replicates,\n\
                 \x20        --depth/--width/--seed/--evals/--config like `repro sim`\n\
                 serve    the coordinator service: scenario x strategy x replicate FL\n\
                 \x20        sessions multiplexed over one broker, persisted per round;\n\
                 \x20        --scenarios builtin|DIR --filter SUBSTR --strategies a,b,c\n\
                 \x20        --rounds N --replicates R --env analytic|event-driven|live\n\
                 \x20        --threads N --store noop|dir [--store-dir DIR] --metrics CSV\n\
                 \x20        --round-limit N --retries N --dynamics SCENARIO\n\
                 \x20        --faults PLAN.toml (deterministic fault injection at the\n\
                 \x20        broker/store/round/heartbeat seams; see `repro chaos`)\n\
                 \x20        (--store dir makes runs resumable: a killed serve continues\n\
                 \x20        each session from its last completed round)\n\
                 chaos    deterministic chaos soak: tiny env sessions drained under a\n\
                 \x20        --faults PLAN.toml; checks every session reaches a terminal\n\
                 \x20        phase and prints the injected-fault counters. Same plan +\n\
                 \x20        seed => byte-identical --metrics CSV, any --threads;\n\
                 \x20        --sessions N --rounds N --seed S --strategies a,b,c\n\
                 \x20        --threads N --store noop|dir [--store-dir DIR]\n\
                 \x20        --round-limit N --retries N --metrics CSV\n\
                 ablate   per-mechanism ablation of a dynamic scenario (one-mechanism-off deltas);\n\
                 \x20        --scenario NAME [--scenarios builtin|DIR] --mechanisms k1,k2\n\
                 \x20        --strategy pso --evals N --replicates R --threads N --out csv\n\
                 bench    delay-oracle perf suite (evals/sec at tiny/paper/deep/mega10k,\n\
                 \x20        plus delta-path + sharded cases at mega100k/mega1M);\n\
                 \x20        --suite eval [--samples 30 --warmup 3 --batch 32 --threads 4]\n\
                 \x20        [--out BENCH_eval.json]  (JSON schema-validated on write)\n\
                 e2e      end-to-end PSO-placed federated training\n\
                 broker   standalone TCP pub/sub broker\n\
                 worker   one FL client process attached to a TCP broker\n\
                 obs      telemetry snapshot; `obs dump` prints every metric\n\
                 \x20        (--addr HOST:PORT scrapes a live `serve --metrics-addr`\n\
                 \x20        endpoint instead of dumping this process)\n\
                 \n\
                 global observability flags (any subcommand):\n\
                 \x20 --log-level error|warn|info|debug|trace   overrides REPRO_LOG\n\
                 \x20 --trace-out trace.json   record spans, write Chrome trace JSON at exit\n\
                 \x20 --obs-dump               print the metric snapshot at exit\n\
                 \x20 (serve only: --metrics-addr HOST:PORT serves Prometheus text at\n\
                 \x20  GET /metrics; --linger SECS keeps it up after the drain for scrapes)\n\
                 \n\
                 choosing a strategy (--strategy / --strategies):\n\
                 \x20 pso           the paper's Flag-Swap PSO (default; in sim: exact Algorithm 1)\n\
                 \x20 pso-batched   synchronous PSO, whole swarm scored per dispatch\n\
                 \x20 adaptive-pso  Flag-Swap + drift detection and swarm restart\n\
                 \x20 random        SDFLMQ's random baseline\n\
                 \x20 round-robin   SDFLMQ's uniform rotation (alias: uniform)\n\
                 \x20 ga | sa | tabu  black-box meta-heuristic comparators (ablation A2)\n\
                 \x20 sharded-pso   region-local sub-swarms + epoch-barrier incumbent\n\
                 \x20               exchange (aliases: flag-swap-sharded, sharded)\n\
                 Pick pso for the paper's behavior, adaptive-pso for drifting\n\
                 systems, random/round-robin as baselines, ga/sa/tabu to\n\
                 benchmark alternative optimizers under the same budget, and\n\
                 sharded-pso for thread-scalable search at large slot counts.\n\
                 \n\
                 choosing a delay oracle (--env, sim/fleet tier):\n\
                 \x20 analytic      closed-form Eq. 6-7 TPD (default)\n\
                 \x20 event-driven  discrete-event virtual-time round (alias: des);\n\
                 \x20               enable churn/dropout/stragglers/jitter via the\n\
                 \x20               [des]/[net]/[dynamics] tables of --config TOML\n\
                 \n\
                 ablatable mechanisms (--mechanisms, ablate tier):\n\
                 \x20 dynamics.dropout | dynamics.churn | dynamics.straggler | dynamics.drift |\n\
                 \x20 dynamics.corr_fail | dynamics.partition | net.jitter | net.contention |\n\
                 \x20 net.asym   (default: every mechanism the scenario enables)"
            );
            std::process::exit(2);
        }
    };
    // Write trace/dump artifacts even when the subcommand failed; a
    // command error still outranks an artifact-write error.
    let finish = finish_observability(&args);
    result.and(finish)
}

/// Apply the global observability flags before dispatch: `--log-level`
/// overrides `REPRO_LOG`, `--trace-out` arms span recording.
fn init_observability(args: &Args) -> Result<()> {
    if let Some(level) = args.flag("log-level") {
        let parsed = repro::logging::Level::parse(level).ok_or_else(|| {
            anyhow!("--log-level: expected error|warn|info|debug|trace, got {level:?}")
        })?;
        repro::logging::set_level(parsed);
    }
    if args.flag("trace-out").is_some() {
        repro::obs::set_tracing(true);
    }
    Ok(())
}

/// Emit the deferred observability artifacts after the subcommand ran
/// (whether it succeeded or not): the Chrome trace file and/or the
/// metric dump.
fn finish_observability(args: &Args) -> Result<()> {
    if let Some(path) = args.flag("trace-out") {
        let spans = repro::obs::write_chrome_trace(std::path::Path::new(path))
            .with_context(|| format!("--trace-out {path}"))?;
        let dropped = repro::obs::dropped_spans();
        eprintln!(
            "trace: {spans} span(s) -> {path} ({dropped} dropped; open in ui.perfetto.dev)"
        );
    }
    if args.bool_flag("obs-dump") {
        repro::obs::register_builtin();
        print!("{}", repro::obs::render_dump(&repro::obs::snapshot()));
    }
    Ok(())
}

/// `repro obs dump [--addr HOST:PORT]` — print every metric family.
/// With `--addr`, scrape a live `serve --metrics-addr` endpoint and
/// print the Prometheus exposition verbatim; without it, dump this
/// process's own registry in the human-readable format.
fn cmd_obs(args: &Args) -> Result<()> {
    let verb = args.positional.first().map(|s| s.as_str()).unwrap_or("dump");
    if verb != "dump" {
        return Err(anyhow!("unknown obs subcommand {verb:?}; available: dump"));
    }
    match args.flag("addr") {
        Some(addr) => {
            let body = repro::obs::scrape(addr).with_context(|| format!("scrape {addr}"))?;
            print!("{body}");
        }
        None => {
            repro::obs::register_builtin();
            print!("{}", repro::obs::render_dump(&repro::obs::snapshot()));
        }
    }
    Ok(())
}

fn scenario_from_args(args: &Args) -> Result<SimScenario> {
    let mut sc = SimScenario::default();
    if let Some(path) = args.flag("config") {
        let doc =
            repro::configio::TomlDoc::load(std::path::Path::new(path)).map_err(|e| anyhow!(e))?;
        sc = SimScenario::from_toml(&doc).map_err(|e| anyhow!(e))?;
    }
    sc.depth = args.usize_flag("depth", sc.depth).map_err(|e| anyhow!(e))?;
    sc.width = args.usize_flag("width", sc.width).map_err(|e| anyhow!(e))?;
    sc.seed = args.u64_flag("seed", sc.seed).map_err(|e| anyhow!(e))?;
    sc.pso.particles = args
        .usize_flag("particles", sc.pso.particles)
        .map_err(|e| anyhow!(e))?;
    sc.pso.iterations = args
        .usize_flag("iterations", sc.pso.iterations)
        .map_err(|e| anyhow!(e))?;
    Ok(sc)
}

fn cmd_sim(args: &Args) -> Result<()> {
    let mut sc = scenario_from_args(args)?;
    sc.strategy = args.str_flag("strategy", &sc.strategy);
    sc.env = args.str_flag("env", &sc.env);
    println!(
        "sim: strategy={} env={} depth={} width={} clients={} slots={} particles={} iterations={}",
        sc.strategy,
        sc.env,
        sc.depth,
        sc.width,
        sc.client_count(),
        sc.dimensions(),
        sc.pso.particles,
        sc.pso.iterations
    );
    let result = run_sim_with(&sc, &sc.strategy).map_err(|e| anyhow!(e))?;
    let norm = result.trace.normalized();
    println!(
        "{}",
        ascii_plot(
            &format!("normalized TPD vs iteration [{}]", result.strategy),
            &[
                ("worst", 'r', &norm.worst),
                ("mean", 'o', &norm.mean),
                ("best", 'g', &norm.best),
            ],
            72,
            18,
        )
    );
    println!(
        "best TPD {:.4} (placement {:?}), converged={}, {} evaluations",
        result.best_tpd, result.best_placement, result.converged, result.evaluations
    );
    if let Some(out) = args.flag("out") {
        result.trace.write_csv(std::path::Path::new(out))?;
        println!("trace written to {out}");
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.str_flag("out-dir", "results"));
    std::fs::create_dir_all(&out_dir)?;
    for (label, sc) in SimScenario::fig3_panels() {
        let result = run_sim(&sc);
        let path = out_dir.join(format!("fig3_{label}.csv"));
        result.trace.normalized().write_csv(&path)?;
        println!(
            "panel ({label}): D={} W={} P={} clients={} → best TPD {:.4}, converged={} → {}",
            sc.depth,
            sc.width,
            sc.pso.particles,
            sc.client_count(),
            result.best_tpd,
            result.converged,
            path.display()
        );
    }
    Ok(())
}

/// Load `--scenarios builtin|DIR`, optionally filtered by `--filter`.
fn scenarios_from_args(args: &Args) -> Result<Vec<NamedScenario>> {
    use repro::des::{builtin_catalog, load_dir};
    let src = args.str_flag("scenarios", "builtin");
    let mut scenarios = if src == "builtin" {
        builtin_catalog()
    } else {
        load_dir(std::path::Path::new(&src)).map_err(|e| anyhow!(e))?
    };
    // `--filter SUBSTR` keeps only matching scenario names (e.g.
    // `--filter tiny` for a smoke run over the smallest populations).
    if let Some(filter) = args.flag("filter") {
        scenarios.retain(|s| s.name.contains(filter));
        if scenarios.is_empty() {
            return Err(anyhow!("--filter {filter:?} matched no scenario"));
        }
    }
    Ok(scenarios)
}

/// Scenario × strategy matrix on the discrete-event simulator, across
/// OS threads, with a ranked summary + CSV — the scale/dynamics tier
/// (`repro fleet --scenarios builtin --strategies pso,random,...`).
/// `--replicates MIN..MAX` (inclusive) switches on the adaptive
/// allocator: scenarios whose leader separates early stop spending
/// replicates.
fn cmd_fleet(args: &Args) -> Result<()> {
    let scenarios = scenarios_from_args(args)?;
    let strategies = args.list_flag("strategies").unwrap_or_else(|| {
        registry::NAMES.iter().map(|s| s.to_string()).collect()
    });
    let threads = args.usize_flag("threads", 0).map_err(|e| anyhow!(e))?;
    let replicates =
        ReplicateRange::parse(&args.str_flag("replicates", "1")).map_err(|e| anyhow!(e))?;
    let plan = ExperimentPlan {
        scenarios,
        strategies,
        evals: args.opt_usize_flag("evals").map_err(|e| anyhow!(e))?,
        env_override: None,
        replicates,
    };
    let rep_str = if replicates.is_fixed() {
        format!("{}", replicates.min)
    } else {
        format!("{}..{} (adaptive)", replicates.min, replicates.max)
    };
    println!(
        "fleet: {} scenarios ({}) × {} strategies × {} replicates, threads={}",
        plan.scenarios.len(),
        args.str_flag("scenarios", "builtin"),
        plan.strategies.len(),
        rep_str,
        if threads == 0 { "auto".to_string() } else { threads.to_string() },
    );
    let cells = run_plan(&plan, &TrialScheduler::new(threads)).map_err(|e| anyhow!(e))?;
    let out = std::path::PathBuf::from(args.str_flag("out", "results/fleet.csv"));
    report_cells(&cells, Some(&out))?;
    Ok(())
}

/// Strategy comparison. `--env live` (default) runs the Fig-4
/// deployment testbed through the coordinator service — `--replicates
/// R` submits R independently seeded live sessions per strategy, all
/// multiplexed over one broker. `--env analytic|event-driven` runs a
/// replicated sim-tier comparison through the experiment engine
/// instead.
fn cmd_compare(args: &Args) -> Result<()> {
    let strategies = args.list_flag("strategies").unwrap_or_default();
    // Fail fast on typos before paying for a deployment run.
    for name in &strategies {
        registry::canonical(name).map_err(|e| anyhow!(e))?;
    }
    let env = args.str_flag("env", "live");
    let replicates =
        ReplicateRange::parse(&args.str_flag("replicates", "1")).map_err(|e| anyhow!(e))?;
    if env == "live" {
        if !replicates.is_fixed() {
            return Err(anyhow!(
                "--env live takes a fixed --replicates R; the adaptive MIN..MAX allocator \
                 is sim-tier only"
            ));
        }
        let rounds = args.usize_flag("rounds", 50).map_err(|e| anyhow!(e))?;
        let time_scale = args.f64_flag("time-scale", 1.0).map_err(|e| anyhow!(e))?;
        let out_dir = std::path::PathBuf::from(args.str_flag("out-dir", "results"));
        let opts = LiveServiceOptions {
            replicates: replicates.min,
            threads: args.usize_flag("threads", 0).map_err(|e| anyhow!(e))?,
            dynamics: dynamics_from_args(args)?,
            store: store_from_args(args)?,
            metrics_path: args.flag("metrics").map(std::path::PathBuf::from),
        };
        return run_live_comparison(rounds, time_scale, &out_dir, &strategies, &opts);
    }
    // Sim-tier replicated comparison: one-scenario plan, any oracle.
    let mut sc = scenario_from_args(args)?;
    sc.env = env;
    let strategies = if strategies.is_empty() {
        repro::sim::DEFAULT_STRATEGIES.iter().map(|s| s.to_string()).collect()
    } else {
        strategies
    };
    let plan = ExperimentPlan {
        scenarios: vec![NamedScenario { name: "compare".into(), sim: sc }],
        strategies,
        evals: args.opt_usize_flag("evals").map_err(|e| anyhow!(e))?,
        env_override: None,
        replicates,
    };
    let threads = args.usize_flag("threads", 0).map_err(|e| anyhow!(e))?;
    let cells = run_plan(&plan, &TrialScheduler::new(threads)).map_err(|e| anyhow!(e))?;
    let out = args.flag("out").map(std::path::PathBuf::from);
    report_cells(&cells, out.as_deref())?;
    Ok(())
}

/// `--store noop|dir [--store-dir DIR]` → a session persistence
/// backend for the coordinator service.
fn store_from_args(args: &Args) -> Result<Arc<dyn Store>> {
    let kind = args.str_flag("store", "noop");
    let store: Arc<dyn Store> = match kind.as_str() {
        "noop" => Arc::new(NoopStore::new()),
        "dir" => {
            let root = args.str_flag("store-dir", "results/service");
            Arc::new(DirStore::open(root)?)
        }
        other => return Err(anyhow!("--store must be noop|dir, got {other:?}")),
    };
    Ok(store)
}

/// `--dynamics NAME` → the named catalog scenario's `[dynamics]` table
/// (the same churn/dropout machinery the DES tier models internally),
/// replayed into service session membership round by round.
fn dynamics_from_args(args: &Args) -> Result<Option<DynamicsSpec>> {
    use repro::des::{builtin_catalog, load_dir};
    let Some(name) = args.flag("dynamics") else {
        return Ok(None);
    };
    let src = args.str_flag("scenarios", "builtin");
    let catalog = if src == "builtin" {
        builtin_catalog()
    } else {
        load_dir(std::path::Path::new(&src)).map_err(|e| anyhow!(e))?
    };
    let Some(ns) = catalog.iter().find(|s| s.name == name) else {
        return Err(anyhow!(
            "--dynamics: unknown scenario {name:?} (try the `repro fleet` catalog names)"
        ));
    };
    Ok(Some(ns.sim.des.dynamics.clone()))
}

/// The coordinator service (`repro serve`): queue scenario × strategy ×
/// replicate FL sessions, drain them over a worker pool with pluggable
/// persistence and a metric sink, and report each session's terminal
/// state. With `--store dir`, a killed serve run resumes every
/// in-flight session from its last completed round on the next
/// invocation; `--round-limit N` pauses sessions after N rounds (the
/// manual way to exercise exactly that resume path).
fn cmd_serve(args: &Args) -> Result<()> {
    let env = args.str_flag("env", "analytic");
    let rounds = args.usize_flag("rounds", 10).map_err(|e| anyhow!(e))?;
    let replicates = args.usize_flag("replicates", 1).map_err(|e| anyhow!(e))?;
    if replicates == 0 {
        return Err(anyhow!("--replicates must be >= 1"));
    }
    let strategies = args
        .list_flag("strategies")
        .unwrap_or_else(|| vec!["pso".to_string()]);
    for name in &strategies {
        registry::canonical(name).map_err(|e| anyhow!(e))?;
    }
    let threads = args.usize_flag("threads", 0).map_err(|e| anyhow!(e))?;
    let round_limit = args.opt_usize_flag("round-limit").map_err(|e| anyhow!(e))?;
    let retries = args.opt_usize_flag("retries").map_err(|e| anyhow!(e))?;
    let dynamics = dynamics_from_args(args)?;
    let store = store_from_args(args)?;
    let recorder: Box<dyn Recorder> = match args.flag("metrics") {
        Some(path) => Box::new(CsvRecorder::create(std::path::Path::new(path))?),
        None => Box::new(NoopRecorder::new()),
    };
    // `--metrics-addr HOST:PORT` serves Prometheus text format at
    // GET /metrics for the whole drain (and the optional --linger tail,
    // so CI and `repro obs dump --addr` can scrape a finished run).
    let metrics_server = match args.flag("metrics-addr") {
        Some(addr) => {
            let server = repro::obs::MetricsServer::start(addr)
                .with_context(|| format!("--metrics-addr {addr}"))?;
            println!("metrics: http://{}/metrics", server.addr());
            Some(server)
        }
        None => None,
    };
    let linger = args.f64_flag("linger", 0.0).map_err(|e| anyhow!(e))?;
    let faults = faults_from_args(args)?;

    let mut cfg = ServiceConfig { threads, round_limit, ..ServiceConfig::default() };
    // Injected store retries back off on the wall clock only when real
    // wall time is in play; env drains stay instant and deterministic.
    cfg.backoff.sleep = env == "live";
    let mut svc = CoordinatorService::new(cfg, store.clone(), recorder);
    if let Some(plan) = &faults {
        svc = svc.with_faults(plan.clone());
    }

    if env == "live" {
        let runtime = Arc::new(
            repro::runtime::ModelRuntime::load_default()
                .context("artifacts required — run `make artifacts`")?,
        );
        svc = svc.with_runtime(runtime);
        let time_scale = args.f64_flag("time-scale", 1.0).map_err(|e| anyhow!(e))?;
        let mut sc = repro::configio::DeployScenario::paper_docker();
        sc.rounds = rounds;
        for strategy in &strategies {
            for r in 0..replicates {
                let session = format!("live-{strategy}-r{r}");
                let mut spec =
                    SessionSpec::live(&session, strategy, rounds, sc.clone(), time_scale);
                spec.seed = Some(replicate_seed(sc.seed, r));
                spec.dynamics = dynamics.clone();
                spec.retry_budget = retries;
                svc.submit(spec)?;
            }
        }
    } else {
        for ns in &scenarios_from_args(args)? {
            for strategy in &strategies {
                for r in 0..replicates {
                    let session = format!("{}-{strategy}-r{r}", ns.name);
                    let mut spec =
                        SessionSpec::env(&session, strategy, rounds, ns.sim.clone(), &env);
                    spec.seed = Some(replicate_seed(ns.sim.seed, r));
                    spec.dynamics = dynamics.clone();
                    spec.retry_budget = retries;
                    svc.submit(spec)?;
                }
            }
        }
    }
    println!(
        "serve: {} sessions queued (env={env}, {} strategies x {replicates} replicates, \
         rounds={rounds}, store={}, threads={})",
        svc.pending_sessions(),
        strategies.len(),
        store.name(),
        if threads == 0 { "auto".to_string() } else { threads.to_string() },
    );

    let outcomes = svc.drain()?;
    println!(
        "{:<30} {:>10} {:>7} {:>8} {:>12}",
        "session", "phase", "rounds", "resumed", "best (s)"
    );
    let mut failed = 0;
    for out in &outcomes {
        if out.phase == Phase::Failed {
            failed += 1;
        }
        // Manual Display impls ignore format widths; pad the String.
        let phase = out.phase.to_string();
        let resumed = out
            .resumed_from
            .map(|k| format!("@{k}"))
            .unwrap_or_else(|| "-".into());
        let best = out
            .best
            .as_ref()
            .map(|(_, d)| format!("{d:.3}"))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<30} {:>10} {:>7} {:>8} {:>12}",
            out.name,
            phase,
            out.trace.len(),
            resumed,
            best
        );
    }
    let paused = outcomes.iter().filter(|o| !o.phase.is_terminal()).count();
    if paused > 0 {
        println!(
            "{paused} session(s) paused by --round-limit; rerun with the same --store to resume"
        );
    }
    if let Some(server) = &metrics_server {
        if linger > 0.0 {
            println!("metrics: lingering {linger}s at http://{}/metrics", server.addr());
            std::thread::sleep(std::time::Duration::from_secs_f64(linger));
        }
    }
    drop(metrics_server);
    if failed > 0 {
        return Err(anyhow!("{failed} of {} session(s) failed", outcomes.len()));
    }
    Ok(())
}

/// Parse `--faults PLAN.toml` into a shared fault plan (None when the
/// flag is absent).
fn faults_from_args(args: &Args) -> Result<Option<Arc<repro::fault::FaultPlan>>> {
    match args.flag("faults") {
        Some(path) => {
            let plan = repro::fault::FaultPlan::load(std::path::Path::new(path))
                .with_context(|| format!("--faults {path}"))?;
            Ok(Some(Arc::new(plan)))
        }
        None => Ok(None),
    }
}

/// `repro chaos`: a deterministic chaos soak. Queue `--sessions` tiny
/// env-backed sessions, drain them under the `--faults` plan, and check
/// the recovery invariants: every session reaches a terminal phase
/// (Finished, or Failed with its budget/quarantine paper trail), and —
/// because every fault realization is a pure function of (plan seed,
/// injection point, session, round/attempt) — two invocations with the
/// same plan and seed produce byte-identical `--metrics` CSVs for any
/// thread count. `--round-limit` + `--store dir` turns the soak into a
/// kill/resume stitcher: rerun the same command and resumed sessions
/// must extend their traces bit-identically.
fn cmd_chaos(args: &Args) -> Result<()> {
    let Some(plan) = faults_from_args(args)? else {
        return Err(anyhow!("--faults PLAN.toml required (the plan drives the whole soak)"));
    };
    let sessions = args.usize_flag("sessions", 4).map_err(|e| anyhow!(e))?;
    if sessions == 0 {
        return Err(anyhow!("--sessions must be >= 1"));
    }
    let rounds = args.usize_flag("rounds", 6).map_err(|e| anyhow!(e))?;
    let seed = args.u64_flag("seed", 7).map_err(|e| anyhow!(e))?;
    let threads = args.usize_flag("threads", 0).map_err(|e| anyhow!(e))?;
    let round_limit = args.opt_usize_flag("round-limit").map_err(|e| anyhow!(e))?;
    let retries = args.opt_usize_flag("retries").map_err(|e| anyhow!(e))?;
    let strategies = args
        .list_flag("strategies")
        .unwrap_or_else(|| vec!["pso".to_string(), "round-robin".to_string()]);
    for name in &strategies {
        registry::canonical(name).map_err(|e| anyhow!(e))?;
    }
    let dynamics = dynamics_from_args(args)?;
    let store = store_from_args(args)?;
    let recorder: Box<dyn Recorder> = match args.flag("metrics") {
        Some(path) => Box::new(CsvRecorder::create(std::path::Path::new(path))?),
        None => Box::new(NoopRecorder::new()),
    };

    let cfg = ServiceConfig { threads, round_limit, ..ServiceConfig::default() };
    let mut svc = CoordinatorService::new(cfg, store.clone(), recorder).with_faults(plan.clone());
    for i in 0..sessions {
        let strategy = &strategies[i % strategies.len()];
        let name = format!("chaos-{strategy}-r{i}");
        let mut sim = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        sim.pso.particles = 4;
        let mut spec = SessionSpec::env(&name, strategy, rounds, sim, "analytic");
        spec.seed = Some(replicate_seed(seed, i));
        spec.dynamics = dynamics.clone();
        spec.retry_budget = retries;
        svc.submit(spec)?;
    }
    println!(
        "chaos: {sessions} sessions x {rounds} rounds under plan seed {} (store={})",
        plan.seed,
        store.name()
    );

    let outcomes = svc.drain()?;
    println!("{:<30} {:>10} {:>7} {:>8}  {}", "session", "phase", "rounds", "resumed", "note");
    let (mut finished, mut failed, mut quarantined, mut paused) = (0usize, 0usize, 0usize, 0usize);
    for out in &outcomes {
        let note = out
            .rows
            .iter()
            .rev()
            .find(|r| r.detail.starts_with("quarantined:"))
            .map(|r| r.detail.clone())
            .unwrap_or_default();
        match out.phase {
            Phase::Finished => finished += 1,
            Phase::Failed => failed += 1,
            _ => paused += 1,
        }
        if !note.is_empty() {
            quarantined += 1;
        }
        let resumed = out.resumed_from.map(|k| format!("@{k}")).unwrap_or_else(|| "-".into());
        println!(
            "{:<30} {:>10} {:>7} {:>8}  {note}",
            out.name,
            out.phase.to_string(),
            out.trace.len(),
            resumed
        );
    }
    // The injected-fault paper trail (also on /metrics under serve).
    repro::obs::register_builtin();
    let dump = repro::obs::render_dump(&repro::obs::snapshot());
    for line in dump.lines() {
        if line.starts_with("repro_fault_injected_total")
            || line.starts_with("repro_service_store_retries_total")
            || line.starts_with("repro_service_sessions_quarantined_total")
        {
            println!("{line}");
        }
    }
    println!(
        "chaos: {finished} finished, {failed} failed ({quarantined} quarantined), {paused} paused"
    );
    // Invariant: without a --round-limit pause, every session must have
    // reached a terminal phase — a stuck session is a recovery bug.
    if paused > 0 && round_limit.is_none() {
        return Err(anyhow!("{paused} session(s) stuck in a non-terminal phase"));
    }
    Ok(())
}

/// Per-mechanism ablation: re-run one scenario with each mechanism
/// switched off and report the paired delay deltas with 95% CIs.
fn cmd_ablate(args: &Args) -> Result<()> {
    use repro::exp::{enabled_mechanisms, report_ablation, run_ablation, AblationConfig};
    let name = args
        .flag("scenario")
        .ok_or_else(|| anyhow!("--scenario NAME required (e.g. --scenario paper-contended)"))?;
    let scenarios = scenarios_from_args(args)?;
    let ns = scenarios
        .iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow!("unknown scenario {name:?} (try `repro fleet` names)"))?;
    let mechanisms = args
        .list_flag("mechanisms")
        .unwrap_or_else(|| enabled_mechanisms(ns));
    let cfg = AblationConfig {
        strategy: args.str_flag("strategy", "pso"),
        evals: args.opt_usize_flag("evals").map_err(|e| anyhow!(e))?,
        replicates: args.usize_flag("replicates", 3).map_err(|e| anyhow!(e))?,
    };
    let threads = args.usize_flag("threads", 0).map_err(|e| anyhow!(e))?;
    let sched = TrialScheduler::new(threads);
    let outcome = run_ablation(ns, &mechanisms, &cfg, &sched).map_err(|e| anyhow!(e))?;
    let out = args.flag("out").map(std::path::PathBuf::from);
    report_ablation(&outcome, out.as_deref())?;
    Ok(())
}

/// Delay-oracle throughput suite: evals/sec for the analytic (scratch,
/// delta and legacy pipelines), emulated and event-driven oracles at
/// the four full-matrix catalog shapes, plus restricted delta-path
/// cases at the mega scales (100k/1M clients), with an optional
/// schema-validated `BENCH_eval.json` artifact.
fn cmd_bench(args: &Args) -> Result<()> {
    use repro::bench::eval_suite::{print_speedups, run_eval_suite, write_bench_json, SuiteConfig};
    let suite = args.str_flag("suite", "eval");
    if suite != "eval" {
        return Err(anyhow!("unknown bench suite {suite:?}; available suites: eval"));
    }
    let default = SuiteConfig::default();
    let cfg = SuiteConfig {
        samples: args.usize_flag("samples", default.samples).map_err(|e| anyhow!(e))?,
        warmup: args.usize_flag("warmup", default.warmup).map_err(|e| anyhow!(e))?,
        batch: args.usize_flag("batch", default.batch).map_err(|e| anyhow!(e))?,
        threads: args.usize_flag("threads", default.threads).map_err(|e| anyhow!(e))?,
    };
    if cfg.samples == 0 || cfg.batch == 0 || cfg.threads == 0 {
        return Err(anyhow!("--samples, --batch and --threads must be >= 1"));
    }
    println!(
        "bench suite=eval samples={} warmup={} batch={} threads={} \
         (latencies are per {}-candidate batch; threads apply to sharded/* cases)",
        cfg.samples, cfg.warmup, cfg.batch, cfg.threads, cfg.batch
    );
    let cases = run_eval_suite(&cfg);
    print_speedups(&cases);
    if let Some(out) = args.flag("out") {
        let path = std::path::PathBuf::from(out);
        write_bench_json(&path, &cfg, &cases).map_err(|e| anyhow!(e))?;
        println!("bench JSON written and schema-validated: {}", path.display());
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let rounds = args.usize_flag("rounds", 50).map_err(|e| anyhow!(e))?;
    repro::sim::run_e2e(rounds)
}

/// One FL client as its own OS process, attached to a TCP broker — the
/// multi-process deployment mode (each paper "docker container" becomes
/// one `repro worker`).
fn cmd_worker(args: &Args) -> Result<()> {
    use repro::broker::TcpPubSub;
    use repro::configio::ClientSpec;
    use repro::data::{SynthConfig, SynthDataset};
    use repro::fl::{ClientAgent, EmulatedClock};
    use repro::runtime::ModelRuntime;
    use std::sync::Arc;

    let id = args.usize_flag("id", 0).map_err(|e| anyhow!(e))?;
    let session = args.str_flag("session", "dist");
    let broker_addr = args.str_flag("broker", "127.0.0.1:1883");
    let speed = args.f64_flag("speed", 1.0).map_err(|e| anyhow!(e))?;
    let mem = args.f64_flag("mem", 1.0).map_err(|e| anyhow!(e))?;
    let time_scale = args.f64_flag("time-scale", 1.0).map_err(|e| anyhow!(e))?;
    let data_seed = args.u64_flag("data-seed", 1234).map_err(|e| anyhow!(e))?;

    let runtime = Arc::new(ModelRuntime::load_default()?);
    let mut clock = EmulatedClock::new(ClientSpec {
        name: format!("worker{id}"),
        speed_factor: speed,
        memory_pressure: mem,
    });
    clock.time_scale = time_scale;
    let data = SynthDataset::for_client(
        SynthConfig {
            input_dim: runtime.meta.input_dim,
            num_classes: runtime.meta.num_classes,
            samples_per_client: 64,
            seed: data_seed,
            ..SynthConfig::default()
        },
        id,
    );
    let addr: std::net::SocketAddr = broker_addr.parse().map_err(|e| anyhow!("--broker: {e}"))?;
    let transport = TcpPubSub::connect(&addr)?;
    // Give the server a beat to register the control subscriptions that
    // ClientAgent::new issues before the session starts.
    println!("worker {id} attached to {addr} (session {session})");
    let agent = ClientAgent::new(
        id,
        &session,
        clock,
        runtime,
        data,
        transport,
        std::time::Duration::from_secs(120),
    );
    agent.run();
    println!("worker {id} shut down");
    Ok(())
}

fn cmd_broker(args: &Args) -> Result<()> {
    let addr = args.str_flag("addr", "127.0.0.1:1883");
    let broker = repro::broker::Broker::new();
    let server = repro::broker::TcpBrokerServer::start(&addr, broker).map_err(|e| anyhow!(e))?;
    println!("broker listening on {}", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
