//! Fault-plane integration: the chaos invariants, end to end through
//! the coordinator service. An empty plan must be byte-neutral on the
//! metrics CSV; a non-trivial plan must realize the *same* faults (and
//! therefore the same CSV bytes) for any thread count; a planned panic
//! must quarantine exactly its target session while every other session
//! completes untouched.

use repro::configio::SimScenario;
use repro::fault::{FaultPlan, HeartbeatFaultCfg, RoundFaultCfg, StoreFaultCfg};
use repro::service::{
    CoordinatorService, CsvRecorder, NoopStore, Phase, Recorder, ServiceConfig, SessionOutcome,
    SessionSpec,
};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn tiny_spec(name: &str, strategy: &str, rounds: usize, seed: u64) -> SessionSpec {
    let mut sim = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
    sim.seed = seed;
    sim.pso.particles = 4;
    SessionSpec::env(name, strategy, rounds, sim, "analytic")
}

/// Drain four tiny sessions through a CSV recorder, optionally under a
/// fault plan, and return (csv bytes, outcomes).
fn drain_to_csv(
    path: &Path,
    threads: usize,
    plan: Option<Arc<FaultPlan>>,
) -> (String, Vec<SessionOutcome>) {
    let recorder: Box<dyn Recorder> = Box::new(CsvRecorder::create(path).unwrap());
    let cfg = ServiceConfig { threads, ..ServiceConfig::default() };
    let mut svc = CoordinatorService::new(cfg, Arc::new(NoopStore::new()), recorder);
    if let Some(plan) = plan {
        svc = svc.with_faults(plan);
    }
    for (i, strategy) in ["pso", "ga", "random", "round-robin"].iter().enumerate() {
        let name = format!("s{i}-{strategy}");
        svc.submit(tiny_spec(&name, strategy, 5, 40 + i as u64)).unwrap();
    }
    let outcomes = svc.drain().unwrap();
    (std::fs::read_to_string(path).unwrap(), outcomes)
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("repro_fault_injection");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.csv"))
}

#[test]
fn an_empty_fault_plan_is_byte_neutral_through_the_whole_service() {
    // The full fault plane armed with an all-zero plan: broker
    // interceptor installed, store wrapped, every backend wrapped —
    // and nothing may change, down to the last CSV byte.
    let (off, out_off) = drain_to_csv(&scratch("neutral_off"), 2, None);
    let (on, out_on) = drain_to_csv(&scratch("neutral_on"), 2, Some(Arc::new(FaultPlan::empty())));
    assert!(!off.is_empty());
    assert_eq!(off, on, "empty plan must be byte-neutral on the metrics CSV");
    for (a, b) in out_off.iter().zip(&out_on) {
        assert_eq!(a.phase, Phase::Finished, "{}", a.name);
        assert_eq!(b.phase, Phase::Finished, "{}", b.name);
        let ta: Vec<u64> = a.trace.iter().map(|r| r.delay_s.to_bits()).collect();
        let tb: Vec<u64> = b.trace.iter().map(|r| r.delay_s.to_bits()).collect();
        assert_eq!(ta, tb, "{}", a.name);
    }
}

/// A plan that exercises every env-reachable fault kind: round errors,
/// a pinpointed worker panic, heartbeat-loss bursts and store IO
/// errors (recovered by the service's retry layer).
fn chaos_plan() -> Arc<FaultPlan> {
    Arc::new(FaultPlan {
        seed: 2026,
        rounds: RoundFaultCfg {
            error_prob: 0.15,
            panic_prob: 0.0,
            panic_at: vec![("s1-ga".to_string(), 2)],
        },
        heartbeats: HeartbeatFaultCfg { loss_prob: 0.05, burst_len: 2 },
        store: StoreFaultCfg { save_fail_prob: 0.10, ..StoreFaultCfg::default() },
        ..FaultPlan::empty()
    })
}

#[test]
fn fault_realizations_are_identical_for_any_thread_count() {
    // Every fault decision is a pure function of (plan seed, injection
    // point, session, key) — never of scheduling — so a serial and a
    // 4-wide drain must realize the same faults and write the same CSV.
    let (serial, out_serial) = drain_to_csv(&scratch("chaos_t1"), 1, Some(chaos_plan()));
    let (wide, out_wide) = drain_to_csv(&scratch("chaos_t4"), 4, Some(chaos_plan()));
    assert_eq!(serial, wide, "fault realizations must not depend on thread count");
    for (a, b) in out_serial.iter().zip(&out_wide) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.phase, b.phase, "{}", a.name);
    }
    // The pinpointed panic quarantined exactly its target...
    let ga = out_serial.iter().find(|o| o.name == "s1-ga").unwrap();
    assert_eq!(ga.phase, Phase::Failed);
    assert!(
        ga.rows.iter().any(|r| r.detail.starts_with("quarantined: injected worker panic")),
        "missing quarantine row for s1-ga"
    );
    assert!(serial.contains("quarantined: injected worker panic"));
    // ...and every session still reached a terminal phase — the chaos
    // soak's core invariant.
    for out in &out_serial {
        assert!(out.phase.is_terminal(), "{} stuck in {:?}", out.name, out.phase);
    }
}

#[test]
fn rerunning_the_same_plan_reproduces_the_csv_byte_for_byte() {
    let (a, _) = drain_to_csv(&scratch("repeat_a"), 2, Some(chaos_plan()));
    let (b, _) = drain_to_csv(&scratch("repeat_b"), 2, Some(chaos_plan()));
    assert_eq!(a, b, "same plan + same sessions must reproduce the CSV exactly");
}
