//! Instrumentation-neutrality suite: the telemetry layer must be
//! invisible in every frozen artifact. The same seeded fleet plan runs
//! with span tracing off and on; the matrix, significance and effect
//! CSVs must come out byte-identical, while the metric registry proves
//! the instrumentation actually fired. A telemetry change that draws
//! from any RNG stream, reorders trials, or perturbs a single delay
//! value trips this suite.

use repro::des::builtin_catalog;
use repro::exp::{report_cells, run_plan, ExperimentPlan, ReplicateRange, TrialScheduler};
use repro::obs;
use std::sync::Mutex;

/// Both tests toggle the process-global tracing flag and span ring;
/// serialize them (a poisoned lock from an earlier panic still
/// excludes).
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn trace_serialized() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn tiny_plan() -> ExperimentPlan {
    ExperimentPlan {
        scenarios: builtin_catalog()
            .into_iter()
            .filter(|s| s.name.starts_with("tiny"))
            .collect(),
        strategies: ["pso", "random", "round-robin"].iter().map(|s| s.to_string()).collect(),
        evals: Some(12),
        env_override: None,
        replicates: ReplicateRange::fixed(2),
    }
}

fn run_and_read(dir: &std::path::Path, tag: &str, threads: usize) -> (String, String, String) {
    let cells = run_plan(&tiny_plan(), &TrialScheduler::new(threads)).unwrap();
    let path = dir.join(format!("neutrality_{tag}.csv"));
    report_cells(&cells, Some(&path)).unwrap();
    let matrix = std::fs::read_to_string(&path).unwrap();
    let sig = std::fs::read_to_string(dir.join(format!("neutrality_{tag}.sig.csv"))).unwrap();
    let effect =
        std::fs::read_to_string(dir.join(format!("neutrality_{tag}.effect.csv"))).unwrap();
    (matrix, sig, effect)
}

fn counter_value(name: &str) -> u64 {
    for family in obs::snapshot() {
        if family.name == name {
            if let obs::FamilyValue::Counter(v) = family.value {
                return v;
            }
        }
    }
    0
}

#[test]
fn fleet_csvs_are_byte_identical_with_telemetry_on_and_off() {
    let _serial = trace_serialized();
    let dir = std::env::temp_dir().join("repro_obs_neutrality");
    let _ = std::fs::remove_dir_all(&dir);

    // Baseline: tracing off (the default), spans ring clear.
    obs::set_tracing(false);
    obs::reset_spans();
    let evals_before = counter_value("repro_placement_evals_total");
    let off = run_and_read(&dir, "off", 2);

    // Same plan with the full telemetry surface armed: span recording
    // on and every counter/histogram live (they are always live — the
    // point is that arming *more* of the layer changes nothing).
    obs::set_tracing(true);
    let on = run_and_read(&dir, "on", 2);
    obs::set_tracing(false);

    assert_eq!(off.0, on.0, "matrix CSV must be byte-identical with tracing on");
    assert_eq!(off.1, on.1, "significance CSV must be byte-identical with tracing on");
    assert_eq!(off.2, on.2, "effect CSV must be byte-identical with tracing on");

    // Prove the runs were actually observed: the eval counter moved...
    let evals_after = counter_value("repro_placement_evals_total");
    assert!(
        evals_after > evals_before,
        "placement eval counter did not move ({evals_before} -> {evals_after})"
    );
    // ...and the traced run captured spans (exp trial spans at minimum).
    let spans = obs::collect_spans();
    assert!(!spans.is_empty(), "tracing-on run must have recorded spans");
    obs::reset_spans();
}

#[test]
fn chrome_trace_export_is_valid_json_with_both_clock_domains() {
    let _serial = trace_serialized();
    // A traced DES-backed run must yield a parseable Chrome trace with
    // wall-clock (exp trial) spans; virtual-clock spans come from the
    // service tier and are exercised in service tests — here we pin the
    // export format end to end through the public API.
    obs::set_tracing(true);
    obs::reset_spans();
    let plan = ExperimentPlan {
        scenarios: builtin_catalog()
            .into_iter()
            .filter(|s| s.name == "tiny-static")
            .collect(),
        strategies: vec!["pso".to_string()],
        evals: Some(12),
        env_override: None,
        replicates: ReplicateRange::fixed(1),
    };
    run_plan(&plan, &TrialScheduler::new(1)).unwrap();
    obs::record_virtual("round", "service", 1, 0.5, 1.25, Some("synthetic r1".into()));
    obs::set_tracing(false);

    let json = obs::render_chrome_trace(&obs::collect_spans());
    let doc = repro::json::parse(&json).expect("chrome trace must be valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_array())
        .expect("traceEvents array");
    // Both clock domains present: pid 1 = wall, pid 2 = virtual.
    let pid_of = |e: &repro::json::Value| e.get("pid").and_then(|p| p.as_f64());
    let complete: Vec<_> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .collect();
    assert!(!complete.is_empty(), "no complete-span events in trace");
    assert!(complete.iter().any(|e| pid_of(e) == Some(1.0)), "no wall-clock spans");
    assert!(complete.iter().any(|e| pid_of(e) == Some(2.0)), "no virtual-clock spans");
    // The synthetic virtual span's duration is (1.25 - 0.5)s in µs.
    let virt = complete
        .iter()
        .find(|e| pid_of(e) == Some(2.0))
        .unwrap();
    assert_eq!(virt.get("dur").and_then(|d| d.as_f64()), Some(750_000.0));
    obs::reset_spans();
}
