//! Integration tests for the statistical fleet runner and the
//! experiment engine behind it: thread-count independence at the CSV
//! byte level (fixed and adaptive replicate allocation), golden
//! coverage of the CI/significance/effect columns, and the
//! adaptive-pso-vs-pso drift study the ROADMAP asks for.

use repro::configio::SimScenario;
use repro::des::{
    builtin_catalog, report_fleet, run_fleet, significance_matrix, standings, FleetConfig,
    NamedScenario,
};
use repro::exp::{run_plan, ExperimentPlan, ReplicateRange, TrialScheduler};

/// The statistical fleet CSV schemas (golden): any column rename or
/// reorder is a deliberate, test-visible change. The matrix and sig
/// schemas are frozen at their PR 3 shape — the engine refactor must
/// reproduce them byte for byte at a fixed `--replicates R`; the new
/// Wilcoxon/effect-size statistics live in their own `.effect.csv`.
const MATRIX_HEADER: &str = "scenario,strategy,clients,slots,evaluations,replicates,\
                             best_delay_mean,best_delay_ci95,mean_delay,rank";
const SIG_HEADER: &str = "best_strategy,vs_strategy,best_wins,losses,ties,p_value";
const EFFECT_HEADER: &str = "best_strategy,vs_strategy,pairs,w_plus,w_minus,wilcoxon_p,effect_size";

fn tiny_scenarios() -> Vec<NamedScenario> {
    builtin_catalog().into_iter().filter(|s| s.name.starts_with("tiny")).collect()
}

#[test]
fn fleet_csv_is_byte_identical_across_thread_counts() {
    // A small builtin matrix (every tiny-population variant, including
    // the correlated-failure / partition / asymmetric-bandwidth ones) at
    // --threads 1 vs --threads 4 with --replicates 3: the report files
    // must come out byte-identical.
    let scenarios = tiny_scenarios();
    assert!(scenarios.len() >= 9, "tiny slice should cover all variants");
    let strategies: Vec<String> = ["pso", "random"].iter().map(|s| s.to_string()).collect();
    let cfg = |threads| FleetConfig { threads, evals: Some(12), replicates: 3 };

    let dir = std::env::temp_dir().join("repro_fleet_integration");
    let _ = std::fs::remove_dir_all(&dir);
    let write = |threads: usize, tag: &str| -> (String, String, String) {
        let cells = run_fleet(&scenarios, &strategies, &cfg(threads)).unwrap();
        let path = dir.join(format!("fleet_{tag}.csv"));
        report_fleet(&cells, Some(&path)).unwrap();
        let matrix = std::fs::read_to_string(&path).unwrap();
        let sig = std::fs::read_to_string(dir.join(format!("fleet_{tag}.sig.csv"))).unwrap();
        let effect =
            std::fs::read_to_string(dir.join(format!("fleet_{tag}.effect.csv"))).unwrap();
        (matrix, sig, effect)
    };
    let (matrix1, sig1, effect1) = write(1, "t1");
    let (matrix4, sig4, effect4) = write(4, "t4");
    assert_eq!(matrix1, matrix4, "matrix CSV must not depend on --threads");
    assert_eq!(sig1, sig4, "significance CSV must not depend on --threads");
    assert_eq!(effect1, effect4, "effect CSV must not depend on --threads");

    // Golden column coverage for the statistics.
    assert_eq!(matrix1.lines().next().unwrap(), MATRIX_HEADER);
    assert_eq!(sig1.lines().next().unwrap(), SIG_HEADER);
    assert_eq!(effect1.lines().next().unwrap(), EFFECT_HEADER);
    assert_eq!(matrix1.lines().count(), 1 + scenarios.len() * strategies.len());
    assert_eq!(sig1.lines().count(), 1 + (strategies.len() - 1));
    assert_eq!(effect1.lines().count(), 1 + (strategies.len() - 1));
    // Every data row carries the replicate count and a parseable,
    // non-negative CI; ranks stay in [1, #strategies].
    for line in matrix1.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols.len(), 10, "{line}");
        assert_eq!(cols[5], "3", "replicates column: {line}");
        let ci: f64 = cols[7].parse().unwrap();
        assert!(ci.is_finite() && ci >= 0.0, "{line}");
        let mean: f64 = cols[6].parse().unwrap();
        assert!(mean.is_finite() && mean > 0.0, "{line}");
        let rank: usize = cols[9].parse().unwrap();
        assert!((1..=strategies.len()).contains(&rank), "{line}");
    }
    // The sign-test row compares the two strategies over all
    // scenario×replicate pairs.
    let sig_cols: Vec<&str> = sig1.lines().nth(1).unwrap().split(',').collect();
    let pairs: usize = sig_cols[2].parse::<usize>().unwrap()
        + sig_cols[3].parse::<usize>().unwrap()
        + sig_cols[4].parse::<usize>().unwrap();
    assert_eq!(pairs, scenarios.len() * 3);
    let p: f64 = sig_cols[5].parse().unwrap();
    assert!((0.0..=1.0).contains(&p), "p-value {p}");
    // The effect row: used pairs ≤ total pairs (exact-zero diffs drop),
    // a valid p and an effect size in [−1, 1].
    let eff_cols: Vec<&str> = effect1.lines().nth(1).unwrap().split(',').collect();
    assert_eq!(eff_cols.len(), 7);
    assert!(eff_cols[2].parse::<usize>().unwrap() <= scenarios.len() * 3);
    let wp: f64 = eff_cols[5].parse().unwrap();
    assert!((0.0..=1.0).contains(&wp), "wilcoxon p {wp}");
    let r: f64 = eff_cols[6].parse().unwrap();
    assert!((-1.0..=1.0).contains(&r), "effect size {r}");
}

#[test]
fn sharded_pso_fleet_csv_is_byte_identical_across_thread_counts() {
    // The sharded optimizer's determinism contract at the fleet level:
    // region-local sub-swarms plus the epoch-barrier exchange must make
    // every report file byte-identical at --threads 1, 2 and 8. Any
    // wall-clock or scheduling leak into the search would break the
    // equality here before it could corrupt a paper run.
    let scenarios = tiny_scenarios();
    let strategies: Vec<String> =
        ["sharded-pso", "pso"].iter().map(|s| s.to_string()).collect();
    let dir = std::env::temp_dir().join("repro_fleet_sharded_integration");
    let _ = std::fs::remove_dir_all(&dir);
    let write = |threads: usize, tag: &str| -> (String, String, String) {
        let cfg = FleetConfig { threads, evals: Some(12), replicates: 2 };
        let cells = run_fleet(&scenarios, &strategies, &cfg).unwrap();
        let path = dir.join(format!("sharded_{tag}.csv"));
        report_fleet(&cells, Some(&path)).unwrap();
        (
            std::fs::read_to_string(&path).unwrap(),
            std::fs::read_to_string(dir.join(format!("sharded_{tag}.sig.csv"))).unwrap(),
            std::fs::read_to_string(dir.join(format!("sharded_{tag}.effect.csv"))).unwrap(),
        )
    };
    let (matrix1, sig1, effect1) = write(1, "t1");
    for (threads, tag) in [(2usize, "t2"), (8, "t8")] {
        let (matrix, sig, effect) = write(threads, tag);
        assert_eq!(matrix1, matrix, "matrix CSV drifted at --threads {threads}");
        assert_eq!(sig1, sig, "sig CSV drifted at --threads {threads}");
        assert_eq!(effect1, effect, "effect CSV drifted at --threads {threads}");
    }
    // Sanity: the sharded strategy actually ran in every scenario row.
    assert_eq!(matrix1.lines().count(), 1 + scenarios.len() * strategies.len());
    assert_eq!(
        matrix1.lines().skip(1).filter(|l| l.contains(",sharded-pso,")).count(),
        scenarios.len()
    );
}

#[test]
fn adaptive_allocation_is_deterministic_across_thread_counts() {
    // The same plan with --replicates 2..10 at --threads 1 vs 8 must
    // yield byte-identical matrix + sig + effect CSVs and identical
    // per-cell replicate counts — the allocator's stop rule reads only
    // completed replicate sets, so thread scheduling cannot leak in.
    let plan = |scenarios: Vec<NamedScenario>| ExperimentPlan {
        scenarios,
        strategies: ["pso", "random", "round-robin"].iter().map(|s| s.to_string()).collect(),
        evals: Some(12),
        env_override: None,
        replicates: ReplicateRange { min: 2, max: 10 },
    };
    let dir = std::env::temp_dir().join("repro_fleet_adaptive_integration");
    let _ = std::fs::remove_dir_all(&dir);
    let write = |threads: usize, tag: &str| -> (Vec<usize>, String, String, String) {
        let cells = run_plan(&plan(tiny_scenarios()), &TrialScheduler::new(threads)).unwrap();
        let path = dir.join(format!("adaptive_{tag}.csv"));
        repro::exp::report_cells(&cells, Some(&path)).unwrap();
        let used = cells.iter().map(|c| c.replicate_delays.len()).collect();
        let matrix = std::fs::read_to_string(&path).unwrap();
        let sig =
            std::fs::read_to_string(dir.join(format!("adaptive_{tag}.sig.csv"))).unwrap();
        let effect =
            std::fs::read_to_string(dir.join(format!("adaptive_{tag}.effect.csv"))).unwrap();
        (used, matrix, sig, effect)
    };
    let (used1, matrix1, sig1, effect1) = write(1, "t1");
    let (used8, matrix8, sig8, effect8) = write(8, "t8");
    assert_eq!(used1, used8, "replicate allocation must not depend on --threads");
    assert_eq!(matrix1, matrix8);
    assert_eq!(sig1, sig8);
    assert_eq!(effect1, effect8);

    // Counts stay inside the range and are uniform within a scenario
    // (paired trials), and the matrix CSV's replicates column reports
    // the per-cell count actually used.
    assert_eq!(used1.len() % 3, 0);
    for chunk in used1.chunks(3) {
        assert!(chunk.iter().all(|&u| (2..=10).contains(&u)), "{chunk:?}");
        assert!(chunk.iter().all(|&u| u == chunk[0]), "unpaired counts {chunk:?}");
    }
    for (line, &used) in matrix1.lines().skip(1).zip(&used1) {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols[5], used.to_string(), "{line}");
    }
    // A fixed plan through the same engine still pins the legacy
    // replicates column everywhere (min == max degenerates exactly).
    let mut fixed = plan(tiny_scenarios());
    fixed.replicates = ReplicateRange::fixed(2);
    let cells = run_plan(&fixed, &TrialScheduler::new(4)).unwrap();
    assert!(cells.iter().all(|c| c.replicate_delays.len() == 2));
}

/// Build one drift-heavy tiny scenario (the ROADMAP's "teach
/// adaptive-pso to exploit EventDrivenEnv's drift" study shape).
fn drift_scenario(name: &str, depth: usize, width: usize, seed: u64) -> NamedScenario {
    let mut sc = SimScenario {
        depth,
        width,
        env: "event-driven".into(),
        ..SimScenario::default()
    };
    sc.seed = seed;
    // Strong speed drift: the per-client random walk reshuffles which
    // clients are fast, so a placement pinned early goes stale.
    sc.des.dynamics.drift_sigma = 0.35;
    sc.des.train_unit = 1.0;
    NamedScenario { name: name.to_string(), sim: sc }
}

#[test]
fn adaptive_pso_tracks_drift_at_least_as_well_as_plain_pso() {
    // The drift study: across >= 5 paired replicates of six drift-heavy
    // scenarios, adaptive-pso (variance-tuned restart detector) must
    // beat or tie plain pso on mean rank. Replicate seeds are shared
    // between the two strategies, so every comparison is under
    // identical drift realizations.
    let scenarios = vec![
        drift_scenario("drift-a", 2, 2, 101),
        drift_scenario("drift-b", 2, 2, 202),
        drift_scenario("drift-c", 2, 2, 303),
        drift_scenario("drift-d", 2, 3, 404),
        drift_scenario("drift-e", 2, 3, 505),
        drift_scenario("drift-f", 2, 3, 606),
    ];
    let strategies: Vec<String> = ["pso", "adaptive-pso"].iter().map(|s| s.to_string()).collect();
    let cfg = FleetConfig { threads: 0, evals: Some(300), replicates: 5 };
    let cells = run_fleet(&scenarios, &strategies, &cfg).unwrap();
    assert!(cells.iter().all(|c| c.replicate_delays.len() == 5));

    let table = standings(&cells);
    let by_name = |n: &str| table.iter().find(|s| s.strategy == n).unwrap();
    let adaptive = by_name("adaptive-pso");
    let plain = by_name("pso");
    assert!(
        adaptive.mean_rank <= plain.mean_rank,
        "adaptive-pso mean rank {} should beat or tie pso {} on drift scenarios \
         (regret {:.3} vs {:.3})",
        adaptive.mean_rank,
        plain.mean_rank,
        adaptive.regret,
        plain.regret
    );
    // The paired sign test over the 30 (scenario, replicate) pairs backs
    // the same direction: adaptive cannot lose significantly.
    let sig = significance_matrix(&cells).unwrap();
    if sig.best == "pso" {
        let row = &sig.versus[0];
        assert!(
            row.sign.p_value > 0.05,
            "pso must not be significantly faster than adaptive-pso under drift: p={}",
            row.sign.p_value
        );
    }
}
