//! Cross-module integration (no artifacts needed): simulation pipeline,
//! failure injection in the FL round flow, strategy-vs-simulator
//! composition, config plumbing.

use repro::broker::Broker;
use repro::configio::{SimScenario, TomlDoc};
use repro::fitness::{tpd, ClientAttrs};
use repro::hierarchy::{Arrangement, HierarchySpec};
use repro::placement::*;
use repro::prng::{Pcg32, Rng};
use repro::pso::PsoConfig;
use repro::sim::{run_sim, SimTrace};
use std::time::Duration;

#[test]
fn full_sim_pipeline_matches_paper_shape() {
    // Panel (a): TPD descends, gbest monotone, trace well-formed.
    let sc = SimScenario::default(); // D3 W4 P10
    let r = run_sim(&sc);
    assert_eq!(r.trace.iterations(), sc.pso.iterations);
    // gbest monotone non-increasing.
    for w in r.trace.gbest.windows(2) {
        assert!(w[1] <= w[0] + 1e-12);
    }
    // Improvement over the initial mean (paper: clear descent).
    assert!(r.best_tpd < r.trace.mean[0] * 0.9);
    // Normalization starts at 1.
    let n = r.trace.normalized();
    assert!((n.worst[0] - 1.0).abs() < 1e-12);
}

#[test]
fn sim_strategies_rank_like_the_paper() {
    // On the simulated TPD landscape with a meaningful budget, PSO's
    // final placement beats the random/uniform average (Fig. 4's order,
    // in simulation form).
    let spec = HierarchySpec::new(3, 4);
    let dims = spec.dimensions();
    let cc = dims + 32;
    let mut rng = Pcg32::seed_from_u64(5);
    let attrs = ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
    let tpd_of =
        |pos: &[usize]| tpd(&Arrangement::from_position(spec, pos, cc), &attrs).total;

    let run = |s: Box<dyn Optimizer>| -> f64 {
        let mut s = Stepwise::new(s);
        let mut last20 = Vec::new();
        for round in 0..100 {
            let p = s.propose(round);
            let t = tpd_of(&p);
            s.feedback(t);
            if round >= 80 {
                last20.push(t);
            }
        }
        last20.iter().sum::<f64>() / last20.len() as f64
    };
    let pso = run(Box::new(PsoPlacement::new(
        dims,
        cc,
        PsoConfig::paper(),
        Pcg32::seed_from_u64(1),
    )));
    let rand = run(Box::new(RandomPlacement::new(dims, cc, Pcg32::seed_from_u64(2))));
    let uni = run(Box::new(RoundRobinPlacement::new(dims, cc)));
    assert!(pso < rand, "pso {pso:.3} !< random {rand:.3}");
    assert!(pso < uni, "pso {pso:.3} !< uniform {uni:.3}");
}

#[test]
fn toml_scenario_drives_sim() {
    let doc = TomlDoc::parse(
        "[sim]\ndepth = 3\nwidth = 2\nseed = 11\n[pso]\nparticles = 4\niterations = 25\n",
    )
    .unwrap();
    let sc = SimScenario::from_toml(&doc).unwrap();
    let r = run_sim(&sc);
    assert_eq!(r.trace.iterations(), 25);
    assert_eq!(r.trace.per_particle.len(), 4);
}

#[test]
fn trace_csv_has_all_series() {
    let mut sc = SimScenario {
        depth: 2,
        width: 2,
        ..SimScenario::default()
    };
    sc.pso.iterations = 10;
    sc.pso.particles = 3;
    let r = run_sim(&sc);
    let path = std::env::temp_dir().join("repro_integration_trace.csv");
    r.trace.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let header = text.lines().next().unwrap();
    assert_eq!(header, "iteration,worst,mean,best,gbest,p0,p1,p2");
    assert_eq!(text.lines().count(), 11);
}

#[test]
fn trace_from_stats_roundtrip_with_runner() {
    // The pre-refactor pipeline (raw Swarm + fitness closure) agrees
    // exactly with the registry-driven run_sim — the acceptance check
    // that the Optimizer/Environment API swap changed no behavior.
    use repro::pso::Swarm;
    let sc = SimScenario {
        depth: 2,
        width: 3,
        ..SimScenario::default()
    };
    let spec = HierarchySpec::new(sc.depth, sc.width);
    let cc = sc.client_count();
    let mut rng = Pcg32::seed_from_u64(sc.seed);
    let attrs = ClientAttrs::sample_population(
        cc,
        sc.pspeed_range,
        sc.memcap_range,
        sc.mdatasize,
        &mut rng,
    );
    let mut swarm = Swarm::new(spec.dimensions(), cc, sc.pso, rng.split());
    let stats = swarm.run(|pos| tpd(&Arrangement::from_position(spec, pos, cc), &attrs).total);
    let trace = SimTrace::from_stats(&stats);
    let r = run_sim(&sc);
    assert_eq!(trace.gbest, r.trace.gbest);
    assert_eq!(trace.per_particle, r.trace.per_particle);
    assert_eq!(trace.mean, r.trace.mean);
    assert_eq!(trace.worst, r.trace.worst);
    assert_eq!(trace.best, r.trace.best);
    assert_eq!(r.best_placement, swarm.gbest_placement());
    assert!((r.best_tpd - -swarm.gbest_fitness).abs() < 1e-12);
}

#[test]
fn registry_strategies_run_the_sim_pipeline() {
    // `repro sim --strategy <name>` works for every registered strategy
    // and writes a plottable trace.
    let mut sc = SimScenario {
        depth: 2,
        width: 2,
        ..SimScenario::default()
    };
    sc.pso.iterations = 20;
    sc.pso.particles = 4;
    for name in repro::placement::registry::NAMES {
        let r = repro::sim::run_sim_with(&sc, name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(r.strategy, name);
        assert!(r.best_tpd.is_finite());
        let path = std::env::temp_dir().join(format!("repro_sim_{name}.csv"));
        r.trace.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() > 1, "{name}: empty trace CSV");
    }
}

// ---------------------------------------------------------------------
// The discrete-event tier (des): conformance + fleet matrix.
// ---------------------------------------------------------------------

#[test]
fn event_driven_env_reproduces_analytic_batch_scores() {
    // Acceptance: with zero jitter, no churn and zero link cost, the
    // EventDrivenEnv must reproduce AnalyticTpd batch scores to 1e-9
    // for identical placements, deterministically across two runs.
    use repro::des::EventDrivenEnv;
    let spec = HierarchySpec::new(3, 4); // the paper's Fig-3 shape
    let dims = spec.dimensions();
    let cc = dims + 32;
    let mut rng = Pcg32::seed_from_u64(21);
    let attrs = ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
    let batch: Vec<Placement> = (0..32)
        .map(|_| Placement::new(rng.sample_distinct(cc, dims)))
        .collect();

    let mut analytic = AnalyticTpd::new(spec, attrs.clone());
    let expect = analytic.eval_batch(&batch).unwrap();

    let mut des = EventDrivenEnv::conformance(spec, attrs.clone());
    let got = des.eval_batch(&batch).unwrap();
    assert_eq!(got.len(), expect.len());
    for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
        assert!(
            (g - e).abs() < 1e-9,
            "placement {i}: des {g} != analytic {e} (|Δ| = {})",
            (g - e).abs()
        );
    }

    // Same-seed determinism: a second, independently constructed run
    // produces bit-identical scores.
    let mut des2 = EventDrivenEnv::conformance(spec, attrs);
    let got2 = des2.eval_batch(&batch).unwrap();
    assert_eq!(got, got2, "two same-seed runs must agree exactly");
}

#[test]
fn fleet_matrix_runs_dynamic_scenarios_deterministically() {
    // A miniature `repro fleet`: built-in-catalog-style scenarios
    // (static + churn + dropout + straggler) × three strategies, run
    // twice with different thread counts — identical cells both times.
    use repro::des::{builtin_catalog, run_fleet, standings, FleetConfig};
    let scenarios: Vec<_> = builtin_catalog()
        .into_iter()
        .filter(|s| s.name.starts_with("tiny") || s.name.starts_with("paper"))
        .collect();
    assert!(scenarios.len() >= 8);
    let strategies: Vec<String> =
        ["pso", "random", "round-robin"].iter().map(|s| s.to_string()).collect();
    let cfg = |threads| FleetConfig { threads, evals: Some(15), ..FleetConfig::default() };
    let a = run_fleet(&scenarios, &strategies, &cfg(1)).unwrap();
    let b = run_fleet(&scenarios, &strategies, &cfg(4)).unwrap();
    assert_eq!(a, b, "fleet results must not depend on thread count");
    assert_eq!(a.len(), scenarios.len() * strategies.len());
    assert!(a.iter().all(|c| c.best_delay.is_finite() && c.best_delay > 0.0));
    let table = standings(&a);
    assert_eq!(table.len(), strategies.len());
    let wins: usize = table.iter().map(|s| s.wins).sum();
    // Competition ranking: at least one winner per scenario (ties share
    // rank 1 and add wins).
    assert!(wins >= scenarios.len(), "wins {wins} < {}", scenarios.len());
}

// ---------------------------------------------------------------------
// Failure injection on the messaging plane (no PJRT required).
// ---------------------------------------------------------------------

#[test]
fn aggregator_timeout_proceeds_with_partial_children() {
    // A "dead trainer" must not wedge the round: the aggregator's wait
    // loop times out and aggregates what arrived. We exercise the wait
    // logic directly through the broker.
    let broker = Broker::new();
    let mut agg = broker.connect("agg");
    agg.subscribe("fl/s/r/0/slot/1").unwrap();

    let publisher = broker.connect("trainer");
    publisher
        .publish("fl/s/r/0/slot/1", b"update-1".to_vec())
        .unwrap();
    // Second trainer never publishes.

    let expected = 2;
    let mut got = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_millis(300);
    while got.len() < expected && std::time::Instant::now() < deadline {
        if let Ok(m) = agg.recv_timeout(Duration::from_millis(50)) {
            got.push(m);
        }
    }
    assert_eq!(got.len(), 1, "must proceed with the one update that arrived");
}

#[test]
fn stale_round_messages_do_not_leak() {
    // Round-scoped topics: an update addressed to round 0 must not be
    // visible to a round-1 subscription.
    let broker = Broker::new();
    let late = broker.connect("late-trainer");
    late.publish("fl/s/r/0/slot/0", b"stale".to_vec()).unwrap();

    let mut agg = broker.connect("agg");
    agg.subscribe("fl/s/r/1/slot/0").unwrap();
    late.publish("fl/s/r/0/slot/0", b"staler".to_vec()).unwrap();
    assert!(agg.try_recv().is_none());
    late.publish("fl/s/r/1/slot/0", b"fresh".to_vec()).unwrap();
    assert_eq!(&**agg.recv_timeout(Duration::from_millis(200)).unwrap().payload, b"fresh");
}

#[test]
fn disconnected_subscriber_does_not_block_publisher() {
    let broker = Broker::new();
    {
        let mut c = broker.connect("doomed");
        c.subscribe("x").unwrap();
        // dropped here
    }
    let p = broker.connect("pub");
    for _ in 0..100 {
        p.publish("x", vec![0u8; 64]).unwrap();
    }
    // Delivered count is 0 (no live subscribers), dropped is 0 (the
    // subscription was removed on drop) — either way the publisher
    // never blocked.
    let (_delivered, dropped) = broker.stats();
    assert_eq!(dropped, 0);
}

#[test]
fn pso_recovers_after_outlier_delays() {
    // Failure injection at the optimizer level: transient delay spikes
    // (e.g. a client thrashing) must not permanently poison the swarm —
    // later clean measurements still converge it.
    let dims = 3;
    let cc = 12;
    let mut s = Stepwise::new(Box::new(PsoPlacement::new(
        dims,
        cc,
        PsoConfig::paper(),
        Pcg32::seed_from_u64(3),
    )));
    let mut rng = Pcg32::seed_from_u64(4);
    let base = |p: &[usize]| -> f64 {
        p.chunks(2).map(|l| *l.iter().max().unwrap() as f64).sum::<f64>() + 1.0
    };
    let mut last = f64::INFINITY;
    for round in 0..150 {
        let p = s.propose(round);
        let mut d = base(&p);
        // 10% of early rounds spike 20x.
        if round < 30 && rng.next_f64() < 0.1 {
            d *= 20.0;
        }
        s.feedback(d);
        last = d;
    }
    assert!(last < 12.0, "should still converge to a good placement, got {last}");
}
