//! Service-tier integration: a killed coordinator resumes every
//! in-flight session from the `dir` storage backend without re-running
//! completed rounds, and N concurrent sessions produce exactly the
//! per-session traces of N sequential runs. Env-backed sessions need no
//! artifacts; the live multiplexing test requires `make artifacts`
//! (skips with a notice otherwise).

use repro::configio::{ClientSpec, DeployScenario, DynamicsSpec, SimScenario};
use repro::pso::PsoConfig;
use repro::runtime::ModelRuntime;
use repro::service::{
    CoordinatorService, DirStore, NoopRecorder, NoopStore, Phase, ServiceConfig, SessionOutcome,
    SessionSpec, Store,
};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

fn service(threads: usize, store: Arc<dyn Store>, limit: Option<usize>) -> CoordinatorService {
    let cfg = ServiceConfig { threads, round_limit: limit, ..ServiceConfig::default() };
    CoordinatorService::new(cfg, store, Box::new(NoopRecorder::new()))
}

/// A tiny env-backed session: depth-2/width-2 hierarchy, 4 particles.
fn tiny_spec(name: &str, strategy: &str, rounds: usize, seed: u64) -> SessionSpec {
    let mut sim = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
    sim.seed = seed;
    sim.pso.particles = 4;
    SessionSpec::env(name, strategy, rounds, sim, "analytic")
}

/// Round/placement/delay triples with the delay at full bit precision.
fn trace_bits(o: &SessionOutcome) -> Vec<(usize, Vec<usize>, u64)> {
    o.trace.iter().map(|t| (t.round, t.placement.clone(), t.delay_s.to_bits())).collect()
}

#[test]
fn killed_coordinator_resumes_from_the_dir_store_without_rerunning_rounds() {
    let dir = std::env::temp_dir().join("repro_service_resume_integration");
    let _ = std::fs::remove_dir_all(&dir);
    let spec = || {
        let mut s = tiny_spec("resume-pso", "pso", 6, 11);
        // Membership churn makes the resumed RNG replay observable: a
        // divergence would draw different dropout masks after round 3.
        s.dynamics = Some(DynamicsSpec { dropout_prob: 0.3, ..DynamicsSpec::default() });
        s
    };

    // Reference: the same session run uninterrupted.
    let reference = {
        let mut svc = service(1, Arc::new(NoopStore::new()), None);
        svc.submit(spec()).unwrap();
        svc.drain().unwrap().pop().unwrap()
    };
    assert_eq!(reference.phase, Phase::Finished);
    assert_eq!(reference.trace.len(), 6);

    // Incarnation 1 executes exactly 3 rounds and is then dropped — the
    // "kill". All surviving state lives in the dir store.
    {
        let store = Arc::new(DirStore::open(&dir).unwrap());
        let mut svc = service(1, store, Some(3));
        svc.submit(spec()).unwrap();
        let paused = svc.drain().unwrap().pop().unwrap();
        assert_eq!(paused.phase, Phase::Round(3));
        assert_eq!(paused.trace.len(), 3);
        assert!(paused.resumed_from.is_none());
    }

    // Incarnation 2: a fresh service over the same directory resumes at
    // round 3 and completes the session.
    let store = Arc::new(DirStore::open(&dir).unwrap());
    assert_eq!(store.sessions().unwrap(), vec!["resume-pso".to_string()]);
    let mut svc = service(1, store.clone(), None);
    svc.submit(spec()).unwrap();
    let resumed = svc.drain().unwrap().pop().unwrap();
    assert_eq!(resumed.phase, Phase::Finished);
    assert_eq!(resumed.resumed_from, Some(3));
    assert_eq!(resumed.trace.len(), 6);

    // No completed round was re-executed: the second incarnation only
    // emitted round events for rounds 3..6.
    let executed: Vec<usize> = resumed
        .rows
        .iter()
        .filter(|r| r.kind == "round")
        .map(|r| r.round.unwrap())
        .collect();
    assert_eq!(executed, vec![3, 4, 5]);

    // The stitched trace (restored rounds + fresh rounds) is
    // bit-identical to the uninterrupted reference — optimizer state,
    // RNG streams and dynamics realizations all survived the kill.
    assert_eq!(trace_bits(&resumed), trace_bits(&reference));

    // The final snapshot on disk is terminal and complete.
    let snap = store.load("resume-pso").unwrap().unwrap();
    assert_eq!(snap.phase, "finished");
    assert_eq!(snap.next_round, 6);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_store_resume_recovers_and_stitches_bit_identically() {
    use repro::fault::{FaultPlan, FaultyStore, StoreFaultCfg};
    let dir_a = std::env::temp_dir().join("repro_service_torn_a");
    let dir_b = std::env::temp_dir().join("repro_service_torn_b");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
    let spec = || {
        let mut s = tiny_spec("torn-pso", "pso", 6, 11);
        s.dynamics = Some(DynamicsSpec { dropout_prob: 0.3, ..DynamicsSpec::default() });
        s
    };

    // Reference: the same session uninterrupted.
    let reference = {
        let mut svc = service(1, Arc::new(NoopStore::new()), None);
        svc.submit(spec()).unwrap();
        svc.drain().unwrap().pop().unwrap()
    };

    // Incarnation 1 leaves a clean round-3 snapshot in store A; a
    // parallel incarnation leaves a round-4 snapshot in store B ("the
    // write that was in flight when the crash hit").
    {
        let store = Arc::new(DirStore::open(&dir_a).unwrap());
        let mut svc = service(1, store, Some(3));
        svc.submit(spec()).unwrap();
        svc.drain().unwrap();
    }
    {
        let store = Arc::new(DirStore::open(&dir_b).unwrap());
        let mut svc = service(1, store, Some(4));
        svc.submit(spec()).unwrap();
        svc.drain().unwrap();
    }
    let newer = DirStore::open(&dir_b).unwrap().load("torn-pso").unwrap().unwrap();

    // Tear store A through the injector: the round-4 state half lands,
    // the optimizer checkpoint half stays at round 3 — exactly what a
    // crash between DirStore's two file writes leaves behind.
    let plan = Arc::new(FaultPlan {
        seed: 5,
        store: StoreFaultCfg { torn_state_prob: 1.0, ..StoreFaultCfg::default() },
        ..FaultPlan::empty()
    });
    let faulty = FaultyStore::new(Arc::new(DirStore::open(&dir_a).unwrap()), plan);
    let err = faulty.save("torn-pso", &newer).unwrap_err().to_string();
    assert!(err.contains("torn"), "{err}");
    let hybrid = DirStore::open(&dir_a).unwrap().load("torn-pso").unwrap().unwrap();
    assert_eq!(hybrid.next_round, 4, "state half must have landed");

    // Incarnation 2 resumes from the torn snapshot: the replay-based
    // optimizer cross-check detects the tear, recovers (the replayed
    // optimizer is authoritative), and the stitched trace is
    // bit-identical to the uninterrupted reference.
    let store = Arc::new(DirStore::open(&dir_a).unwrap());
    let mut svc = service(1, store, None);
    svc.submit(spec()).unwrap();
    let resumed = svc.drain().unwrap().pop().unwrap();
    assert_eq!(resumed.phase, Phase::Finished);
    assert_eq!(resumed.resumed_from, Some(4));
    assert!(
        resumed.rows.iter().any(|r| r.detail.contains("torn save recovered by replay")),
        "recovery must leave a paper trail"
    );
    assert_eq!(trace_bits(&resumed), trace_bits(&reference));
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn concurrent_sessions_match_sequential_single_session_runs() {
    let strategies = ["pso", "ga", "random", "round-robin"];
    let spec_for = |i: usize, strategy: &str| {
        tiny_spec(&format!("s{i}-{strategy}"), strategy, 5, 40 + i as u64)
    };

    // N sequential runs, each session alone in its own service.
    let mut sequential = Vec::new();
    for (i, strategy) in strategies.iter().enumerate() {
        let mut svc = service(1, Arc::new(NoopStore::new()), None);
        svc.submit(spec_for(i, strategy)).unwrap();
        sequential.push(svc.drain().unwrap().pop().unwrap());
    }

    // One service draining all N sessions over 4 workers.
    let mut svc = service(4, Arc::new(NoopStore::new()), None);
    for (i, strategy) in strategies.iter().enumerate() {
        svc.submit(spec_for(i, strategy)).unwrap();
    }
    let parallel = svc.drain().unwrap();

    assert_eq!(parallel.len(), sequential.len());
    for (seq, par) in sequential.iter().zip(&parallel) {
        assert_eq!(seq.name, par.name);
        assert_eq!(par.phase, Phase::Finished, "{}", par.name);
        assert_eq!(trace_bits(seq), trace_bits(par), "{}", seq.name);
        // The full event streams (phases, rounds, scores, seq numbers)
        // are identical too — concurrency is invisible per session.
        assert_eq!(seq.rows, par.rows, "{}", seq.name);
    }
}

fn runtime() -> Option<Arc<ModelRuntime>> {
    static RT: OnceLock<Option<Arc<ModelRuntime>>> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("meta.json").exists() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return None;
        }
        Some(Arc::new(ModelRuntime::load(&dir).expect("load artifacts")))
    })
    .clone()
}

/// Small, fast deploy scenario: 6 full-speed clients, 3 slots.
fn fast_deploy() -> DeployScenario {
    let clients = (0..6)
        .map(|i| ClientSpec {
            name: format!("c{i}"),
            speed_factor: 1.0,
            memory_pressure: 1.0,
        })
        .collect();
    DeployScenario {
        clients,
        depth: 2,
        width: 2,
        rounds: 2,
        local_steps: 1,
        lr: 0.05,
        pso: PsoConfig::paper(),
        seed: 99,
        child_timeout_secs: 120.0,
    }
}

#[test]
fn two_concurrent_live_sessions_multiplex_over_one_broker() {
    let Some(rt) = runtime() else { return };
    let mut svc = service(2, Arc::new(NoopStore::new()), None).with_runtime(rt);
    for strategy in ["pso", "round-robin"] {
        let name = format!("live-{strategy}");
        svc.submit(SessionSpec::live(&name, strategy, 2, fast_deploy(), 0.0)).unwrap();
    }
    let outcomes = svc.drain().unwrap();
    assert_eq!(outcomes.len(), 2);
    for out in &outcomes {
        assert_eq!(out.phase, Phase::Finished, "{}", out.name);
        assert_eq!(out.trace.len(), 2, "{}", out.name);
        // Real rounds: positive wall-clock delays, finite losses.
        assert!(out.trace.iter().all(|t| t.delay_s > 0.0 && t.delay_s < 120.0), "{}", out.name);
        assert!(out.trace.iter().all(|t| t.loss.is_finite()), "{}", out.name);
    }
}
