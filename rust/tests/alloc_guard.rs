//! Allocation-regression guard for the evaluation hot path.
//!
//! A counting global allocator measures how many heap allocations one
//! steady-state `eval_batch` dispatch performs after warmup. The
//! contract: the only allowed allocation is the result `Vec<f64>`
//! itself — scoring never touches the heap, at any population size.
//! A regression (someone reintroducing a per-candidate `Arrangement`,
//! a `Vec<bool>` validator, a fresh event queue, …) trips this test
//! with an allocation count that scales with clients or batch size.
//!
//! The guard lives in its own test binary so no *other* binary's tests
//! share the process; within this binary the counter is global, so the
//! tests additionally serialize on [`COUNTER_LOCK`] — the default
//! libtest harness would otherwise run them on parallel threads and
//! one test's setup allocations would pollute another's counting
//! window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use repro::des::EventDrivenEnv;
use repro::fitness::ClientAttrs;
use repro::hierarchy::HierarchySpec;
use repro::placement::{AnalyticTpd, EmulatedDelay, Environment, Placement};
use repro::prng::{Pcg32, Rng};

struct CountingAlloc;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes the counting windows: every test (setup included) runs
/// under this lock, so a sibling test's allocations can never land in
/// an enabled counter. A poisoned lock (earlier test panicked) is
/// still a valid lock for exclusion purposes.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn serialized() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Allocations performed by `f` (best of three runs, to shrug off any
/// one-off lazy initialization inside the standard library).
fn count_allocs(mut f: impl FnMut()) -> usize {
    let mut best = usize::MAX;
    for _ in 0..3 {
        ALLOCS.store(0, Ordering::SeqCst);
        ENABLED.store(true, Ordering::SeqCst);
        f();
        ENABLED.store(false, Ordering::SeqCst);
        best = best.min(ALLOCS.load(Ordering::SeqCst));
    }
    best
}

fn population(spec: HierarchySpec, trainers_per_leaf: usize, seed: u64) -> Vec<ClientAttrs> {
    let cc = spec.dimensions() + spec.leaf_slots().len() * trainers_per_leaf;
    let mut rng = Pcg32::seed_from_u64(seed);
    ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
}

fn batch(spec: HierarchySpec, cc: usize, count: usize, seed: u64) -> Vec<Placement> {
    let mut rng = Pcg32::seed_from_u64(seed);
    (0..count).map(|_| Placement::new(rng.sample_distinct(cc, spec.dimensions()))).collect()
}

/// The result vector is the single allowed allocation per dispatch
/// (`Vec::with_capacity` = 1 call); anything above this constant means
/// scoring itself touched the heap.
const RESULT_VEC_ALLOCS: usize = 1;

#[test]
fn analytic_eval_batch_steady_state_allocates_only_the_result_vec() {
    let _serial = serialized();
    // Two population scales: u64-bitmask range and the 10k-client
    // word-bitset range. The count must be identical — per-client or
    // per-candidate allocation would scale it.
    let mut counts = Vec::new();
    for (depth, width, tpl) in [(2usize, 2usize, 2usize), (3, 4, 625)] {
        let spec = HierarchySpec::new(depth, width);
        let attrs = population(spec, tpl, 1);
        let cc = attrs.len();
        let candidates = batch(spec, cc, 16, 2);
        let mut env = AnalyticTpd::new(spec, attrs);
        for _ in 0..2 {
            env.eval_batch(&candidates).unwrap(); // warm every buffer
        }
        let n = count_allocs(|| {
            let delays = env.eval_batch(&candidates).unwrap();
            assert_eq!(delays.len(), 16);
        });
        assert!(
            n <= RESULT_VEC_ALLOCS,
            "analytic eval_batch allocated {n}× at {cc} clients (allowed: result vec only)"
        );
        counts.push(n);
    }
    assert_eq!(counts[0], counts[1], "allocation count must not scale with population");
}

#[test]
fn analytic_delta_eval_allocates_nothing() {
    let _serial = serialized();
    // Single-candidate delta evaluations return a bare f64: zero heap
    // traffic once the base is cached.
    let spec = HierarchySpec::new(3, 4);
    let attrs = population(spec, 625, 3);
    let cc = attrs.len();
    let mut env = AnalyticTpd::new(spec, attrs);
    let base = batch(spec, cc, 1, 4).pop().unwrap();
    env.eval(&base).unwrap();
    // One-swap neighbor (the strategies' shared move), prebuilt
    // outside the counted region.
    let mut rng = Pcg32::seed_from_u64(5);
    let mut neighbor = base.as_slice().to_vec();
    let (slot, id) = repro::placement::draw_slot_replacement(&base, cc, &mut rng);
    neighbor[slot] = id;
    let neighbor = Placement::new(neighbor);
    env.eval(&neighbor).unwrap(); // warm
    let n = count_allocs(|| {
        env.eval(&neighbor).unwrap();
        env.eval(&base).unwrap();
    });
    assert_eq!(n, 0, "delta eval must not touch the heap ({n} allocations)");
}

#[test]
fn emulated_eval_batch_steady_state_allocates_only_the_result_vec() {
    let _serial = serialized();
    use repro::configio::ClientSpec;
    let spec = HierarchySpec::new(3, 2);
    let cc = spec.dimensions() + spec.leaf_slots().len() * 40;
    let specs: Vec<ClientSpec> = (0..cc)
        .map(|i| ClientSpec {
            name: format!("c{i}"),
            speed_factor: [1.0, 0.5][i % 2],
            memory_pressure: [1.0, 2.0][i % 2],
        })
        .collect();
    let mut env = EmulatedDelay::new(3, 2, &specs);
    let candidates = batch(spec, cc, 16, 6);
    for _ in 0..2 {
        env.eval_batch(&candidates).unwrap();
    }
    let n = count_allocs(|| {
        env.eval_batch(&candidates).unwrap();
    });
    assert!(n <= RESULT_VEC_ALLOCS, "emulated eval_batch allocated {n}×");
}

#[test]
fn telemetry_increments_allocate_nothing() {
    let _serial = serialized();
    // The obs layer rides the eval/DES/scheduler hot paths, so its
    // steady-state mutations must be pure atomic RMWs. Warm once to
    // absorb the one-time lazy registration (which may allocate a
    // registry slot), then pin the counted window to zero.
    use repro::obs::defs as obs;
    repro::obs::register_builtin();
    obs::PLACEMENT_EVALS.add(1);
    obs::PLACEMENT_CACHE_HITS.inc();
    obs::DES_HEAP_HIGH_WATER.set_max(1);
    obs::EXP_QUEUE_WAIT.observe(1e-4);
    let n = count_allocs(|| {
        for i in 0..256u64 {
            obs::PLACEMENT_EVALS.add(16);
            obs::PLACEMENT_CACHE_HITS.inc();
            obs::PLACEMENT_DELTA_EVALS.add(3);
            obs::DES_EVENTS.add(100);
            obs::DES_HEAP_HIGH_WATER.set_max(i as i64);
            obs::EXP_QUEUE_WAIT.observe(1e-4 * (i + 1) as f64);
            obs::EXP_WORKER_BUSY_US.add(i);
        }
    });
    assert_eq!(n, 0, "metric increments must not touch the heap ({n} allocations)");
}

#[test]
fn disabled_span_checks_allocate_nothing() {
    let _serial = serialized();
    // With tracing off (the default), the span gate is one relaxed
    // load — no heap traffic from the paths that consult it.
    assert!(!repro::obs::tracing_enabled());
    let n = count_allocs(|| {
        for i in 0..256u32 {
            if repro::obs::tracing_enabled() {
                repro::obs::record_virtual("round", "test", i, 0.0, 1.0, None);
            }
        }
    });
    assert_eq!(n, 0, "disabled tracing gate must not touch the heap ({n} allocations)");
}

#[test]
fn sharded_eval_batch_allocations_do_not_scale_with_batch_or_population() {
    let _serial = serialized();
    // ParEvalBatch pays a fixed per-dispatch overhead — the result
    // slots, chunk bookkeeping and two thread spawns for three workers
    // (worker 0 runs inline) — but nothing per candidate or per
    // client: each worker scores its contiguous chunk on its own
    // pre-built scratches. Quadrupling the batch and growing the
    // population ~300× must leave the allocation count unchanged.
    use repro::placement::ParEvalBatch;
    let mut counts = Vec::new();
    for (tpl, nbatch) in [(2usize, 8usize), (625, 32)] {
        let spec = HierarchySpec::new(3, 4);
        let attrs = population(spec, tpl, 11);
        let cc = attrs.len();
        let candidates = batch(spec, cc, nbatch, 12);
        let mut env = ParEvalBatch::new(3, |_| AnalyticTpd::new(spec, attrs.clone()));
        for _ in 0..2 {
            env.eval_batch(&candidates).unwrap(); // warm every worker
        }
        let n = count_allocs(|| {
            let delays = env.eval_batch(&candidates).unwrap();
            assert_eq!(delays.len(), nbatch);
        });
        counts.push(n);
    }
    assert_eq!(
        counts[0], counts[1],
        "sharded dispatch allocations must not scale with batch or population: {counts:?}"
    );
}

#[test]
fn sharded_pso_candidate_batches_stay_inside_the_dispatch_alloc_budget() {
    let _serial = serialized();
    // The batches ShardedPso actually emits (full-placement overlays
    // from region-local sweeps) must score under the same fixed
    // per-dispatch budget as hand-rolled candidates: the steady-state
    // eval path allocates the result vector and the worker bookkeeping,
    // never per candidate, per region or per client. Candidate
    // generation itself allocates, so it stays outside the window.
    use repro::placement::{Optimizer, ParEvalBatch, ShardedConfig, ShardedPso};
    let mut counts = Vec::new();
    let mut lens = Vec::new();
    for (tpl, seed) in [(2usize, 21u64), (625, 22)] {
        let spec = HierarchySpec::new(3, 4);
        let attrs = population(spec, tpl, seed);
        let cc = attrs.len();
        let cfg = ShardedConfig { particles: 12, exchange_every: 4 };
        let mut opt = ShardedPso::from_spec(spec, cc, cfg, Pcg32::seed_from_u64(seed));
        let mut env = ParEvalBatch::new(3, |_| AnalyticTpd::new(spec, attrs.clone()));
        // Drive past bootstrap (and one exchange) outside the counted
        // window so swarm state and worker scratches are warm, then
        // take the next sweep batch as the counted workload.
        let mut round = 0;
        let candidates = loop {
            let batch = opt.propose_batch(round);
            let delays = env.eval_batch(&batch).unwrap();
            opt.observe_batch(&batch, &delays);
            round += 1;
            if round >= 6 {
                break opt.propose_batch(round);
            }
        };
        let n = count_allocs(|| {
            let delays = env.eval_batch(&candidates).unwrap();
            assert_eq!(delays.len(), candidates.len());
        });
        counts.push(n);
        lens.push(candidates.len());
    }
    // Same swarm configuration → same batch shape at both scales; the
    // dispatch cost must match it.
    assert_eq!(lens[0], lens[1], "batch shape should not depend on population");
    assert_eq!(
        counts[0], counts[1],
        "sharded-pso dispatch allocations must not scale with population: {counts:?}"
    );
}

#[test]
fn event_driven_eval_batch_steady_state_allocates_only_the_result_vec() {
    let _serial = serialized();
    // Conformance configuration; the event heap and every per-slot
    // table are clear-and-refill, so after one warm batch (which grows
    // the heap to its high-water mark) re-scoring the same batch must
    // only allocate the result vector.
    let spec = HierarchySpec::new(3, 4);
    let attrs = population(spec, 60, 7); // ~981 clients
    let cc = attrs.len();
    let candidates = batch(spec, cc, 8, 8);
    let mut env = EventDrivenEnv::conformance(spec, attrs);
    for _ in 0..2 {
        env.eval_batch(&candidates).unwrap();
    }
    let n = count_allocs(|| {
        let delays = env.eval_batch(&candidates).unwrap();
        assert_eq!(delays.len(), 8);
    });
    assert!(
        n <= RESULT_VEC_ALLOCS,
        "event-driven eval_batch allocated {n}× at {cc} clients (allowed: result vec only)"
    );
}
