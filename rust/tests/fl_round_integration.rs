//! Full-stack FL integration: broker + agents + coordinator + PJRT
//! runtime, exercising complete rounds end-to-end with every placement
//! strategy. Requires `make artifacts` (skips otherwise).

use repro::configio::{ClientSpec, DeployScenario};
use repro::fl::Deployment;
use repro::placement::{Optimizer, PsoPlacement, RandomPlacement, RoundRobinPlacement};
use repro::prng::Pcg32;
use repro::pso::PsoConfig;
use repro::runtime::ModelRuntime;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

fn runtime() -> Option<Arc<ModelRuntime>> {
    static RT: OnceLock<Option<Arc<ModelRuntime>>> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("meta.json").exists() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return None;
        }
        Some(Arc::new(ModelRuntime::load(&dir).expect("load artifacts")))
    })
    .clone()
}

/// Small, fast scenario: 6 clients, depth-2/width-2 hierarchy (3 slots),
/// no emulated slowdown (time_scale 0).
fn fast_scenario() -> DeployScenario {
    let clients = (0..6)
        .map(|i| ClientSpec {
            name: format!("c{i}"),
            speed_factor: 1.0,
            memory_pressure: 1.0,
        })
        .collect();
    DeployScenario {
        clients,
        depth: 2,
        width: 2,
        rounds: 3,
        local_steps: 1,
        lr: 0.05,
        pso: PsoConfig::paper(),
        seed: 99,
        child_timeout_secs: 120.0,
    }
}

fn run_rounds(strategy: Box<dyn Optimizer>, rounds: usize) -> Option<(Vec<f64>, Vec<f64>)> {
    let rt = runtime()?;
    let sc = fast_scenario();
    let session = format!("test-{}-{}", strategy.name(), rounds);
    let mut dep = Deployment::launch(&sc, &session, rt, strategy, 0.0).expect("launch");
    dep.run(rounds).expect("rounds");
    let delays = dep.coordinator.recorder().delays_secs();
    let losses: Vec<f64> = dep
        .coordinator
        .recorder()
        .records()
        .iter()
        .map(|r| r.loss)
        .collect();
    dep.shutdown();
    Some((delays, losses))
}

#[test]
fn random_placement_rounds_complete() {
    let sc = fast_scenario();
    let dims = sc.dimensions();
    let Some((delays, _)) = run_rounds(
        Box::new(RandomPlacement::new(dims, sc.clients.len(), Pcg32::seed_from_u64(1))),
        3,
    ) else {
        return;
    };
    assert_eq!(delays.len(), 3);
    assert!(delays.iter().all(|&d| d > 0.0 && d < 60.0));
}

#[test]
fn uniform_placement_rounds_complete() {
    let sc = fast_scenario();
    let Some((delays, _)) = run_rounds(
        Box::new(RoundRobinPlacement::new(sc.dimensions(), sc.clients.len())),
        3,
    ) else {
        return;
    };
    assert_eq!(delays.len(), 3);
}

#[test]
fn pso_placement_rounds_complete() {
    let sc = fast_scenario();
    let Some((delays, _)) = run_rounds(
        Box::new(PsoPlacement::new(
            sc.dimensions(),
            sc.clients.len(),
            PsoConfig::paper(),
            Pcg32::seed_from_u64(2),
        )),
        4,
    ) else {
        return;
    };
    assert_eq!(delays.len(), 4);
}

#[test]
fn federated_training_loss_descends() {
    // The global model must improve over rounds — the E2E semantic.
    let sc = fast_scenario();
    let Some((_, losses)) = run_rounds(
        Box::new(RoundRobinPlacement::new(sc.dimensions(), sc.clients.len())),
        6,
    ) else {
        return;
    };
    let first = losses.first().copied().unwrap();
    let last = losses.last().copied().unwrap();
    assert!(
        last < first,
        "loss should descend across rounds: {losses:?}"
    );
}

#[test]
fn heterogeneous_clients_slow_the_round() {
    // With an emulated slow aggregator population, rounds take visibly
    // longer than the full-speed baseline — the signal PSO learns from.
    let Some(rt) = runtime() else { return };
    let mut sc = fast_scenario();
    let fast = {
        let strategy = Box::new(RoundRobinPlacement::new(sc.dimensions(), sc.clients.len()));
        let mut dep = Deployment::launch(&sc, "hetero-fast", rt.clone(), strategy, 0.0).unwrap();
        dep.run(2).unwrap();
        let d = dep.coordinator.recorder().mean_delay_secs();
        dep.shutdown();
        d
    };
    for c in &mut sc.clients {
        c.speed_factor = 3.0;
        c.memory_pressure = 3.0;
    }
    let slow = {
        let strategy = Box::new(RoundRobinPlacement::new(sc.dimensions(), sc.clients.len()));
        let mut dep = Deployment::launch(&sc, "hetero-slow", rt, strategy, 1.0).unwrap();
        dep.run(2).unwrap();
        let d = dep.coordinator.recorder().mean_delay_secs();
        dep.shutdown();
        d
    };
    assert!(
        slow > fast * 1.5,
        "emulated slowdown should be visible: fast {fast:.3}s slow {slow:.3}s"
    );
}

#[test]
fn dead_client_does_not_wedge_the_round() {
    // Failure injection: client 5 exists in the scenario but its process
    // never starts. Its parent aggregator must time out (short child
    // timeout here), aggregate the updates that DID arrive, and the
    // round must still complete.
    use repro::fl::{ClientAgent, Coordinator, CoordinatorConfig, EmulatedClock, ModelCodec};
    let Some(rt) = runtime() else { return };
    let sc = fast_scenario();
    let session = "dead-client-test";
    let broker = repro::broker::Broker::new();
    let mut handles = Vec::new();
    for (id, spec) in sc.clients.iter().enumerate() {
        if id == 5 {
            continue; // the dead client
        }
        let clock = EmulatedClock::new(spec.clone());
        let data = repro::data::SynthDataset::for_client(
            repro::data::SynthConfig {
                input_dim: rt.meta.input_dim,
                num_classes: rt.meta.num_classes,
                samples_per_client: 64,
                seed: sc.seed,
                ..Default::default()
            },
            id,
        );
        let agent = ClientAgent::new(
            id,
            session,
            clock,
            rt.clone(),
            data,
            broker.connect(&spec.name),
            std::time::Duration::from_secs(3), // short child timeout
        );
        handles.push(std::thread::spawn(move || agent.run()));
    }
    let cfg = CoordinatorConfig {
        session: session.into(),
        depth: sc.depth,
        width: sc.width,
        client_count: sc.clients.len(),
        local_steps: 1,
        lr: 0.05,
        codec: ModelCodec::Binary,
        round_timeout: std::time::Duration::from_secs(120),
        eval_every: 0,
        model_seed: [0, 6],
        data_seed: sc.seed,
    };
    // Uniform rotation guarantees client 5 shows up as a trainer and
    // eventually as an aggregator across 4 rounds; rounds must finish
    // either way (aggregator slots held by 5 are the hard case — those
    // rounds wedge only if BOTH the leaf timeout and the coordinator
    // timeout were misconfigured; with 3 slots over 6 clients, client 5
    // is an aggregator in rounds 1 and 3).
    let mut strategy = RoundRobinPlacement::new(sc.dimensions(), sc.clients.len());
    let mut coord = Coordinator::new(cfg, broker.connect("coord"), rt).unwrap();
    // Only run the round where 5 is a trainer (round 0: rotation gives
    // placement {0,1,2}), driving the policy-free execute_round
    // primitive with an explicitly proposed placement.
    let placement = strategy.propose_batch(0).pop().unwrap();
    let rec0 = coord
        .execute_round(0, &placement)
        .expect("round 0 with dead trainer");
    assert!(rec0.delay.as_secs_f64() < 60.0);
    coord.shutdown();
    for h in handles {
        let _ = h.join();
    }
}

#[test]
fn json_codec_session_works() {
    // The paper's JSON wire format end-to-end.
    use repro::fl::{Coordinator, CoordinatorConfig, ModelCodec};
    let Some(rt) = runtime() else { return };
    let sc = fast_scenario();
    let session = "json-codec-test";
    let broker = repro::broker::Broker::new();
    let mut handles = Vec::new();
    for (id, spec) in sc.clients.iter().enumerate() {
        let clock = repro::fl::EmulatedClock::new(spec.clone());
        let data = repro::data::SynthDataset::for_client(
            repro::data::SynthConfig {
                input_dim: rt.meta.input_dim,
                num_classes: rt.meta.num_classes,
                samples_per_client: 64,
                ..Default::default()
            },
            id,
        );
        let agent = repro::fl::ClientAgent::new(
            id,
            session,
            clock,
            rt.clone(),
            data,
            broker.connect(&spec.name),
            std::time::Duration::from_secs(60),
        );
        handles.push(std::thread::spawn(move || agent.run()));
    }
    let cfg = CoordinatorConfig {
        session: session.into(),
        depth: sc.depth,
        width: sc.width,
        client_count: sc.clients.len(),
        local_steps: 1,
        lr: 0.05,
        codec: ModelCodec::Json,
        round_timeout: std::time::Duration::from_secs(120),
        eval_every: 0,
        model_seed: [0, 5],
        data_seed: 1234,
    };
    let mut strategy = RoundRobinPlacement::new(sc.dimensions(), sc.clients.len());
    let mut coord = Coordinator::new(cfg, broker.connect("coord"), rt).unwrap();
    coord.run_session(&mut strategy, 2).expect("json rounds");
    assert_eq!(coord.recorder().len(), 2);
    assert_eq!(coord.recorder().records()[0].strategy, "round-robin");
    coord.shutdown();
    for h in handles {
        let _ = h.join();
    }
}
