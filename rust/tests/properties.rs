//! Property-based tests (proplite) over the coordinator-side invariants:
//! hierarchy arithmetic, arrangement/rearrangement, TPD, PSO state,
//! placement strategies, JSON, codecs.

use repro::configio::DynamicsSpec;
use repro::des::{
    simulate_round, Dynamics, EventDrivenEnv, NetworkModel, RoundRealization, RoundScratch,
    SyncMode,
};
use repro::fitness::{tpd, tpd_with_memory, ClientAttrs, TpdScratch};
use repro::fl::codec::{ModelCodec, ModelUpdate};
use repro::hierarchy::{Arrangement, EvalScratch, HierarchySpec, Role};
use repro::json::{self, Value};
use repro::placement::*;
use repro::proplite::{forall, Gen};
use repro::prng::{Pcg32, Rng};
use repro::pso::{AsyncSwarm, PsoConfig, Swarm};

fn random_spec(g: &mut Gen) -> HierarchySpec {
    HierarchySpec::new(g.usize_in(1..5), g.usize_in(1..5))
}

fn random_population(g: &mut Gen, n: usize) -> Vec<ClientAttrs> {
    let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
    ClientAttrs::sample_population(n, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
}

#[test]
fn prop_hierarchy_slot_arithmetic_consistent() {
    forall("hierarchy slot arithmetic", 200, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        // Eq. 5 closed form.
        let expect: usize = (0..spec.depth).map(|i| spec.width.pow(i as u32)).sum();
        assert_eq!(dims, expect);
        // Every non-root slot's parent's children contain it.
        for s in 1..dims {
            let parent = spec.parent(s).unwrap();
            assert!(spec.children(parent).contains(&s));
        }
        // Level bookkeeping covers all slots exactly once.
        let total: usize = (0..spec.depth).map(|l| spec.level_size(l)).sum();
        assert_eq!(total, dims);
    });
}

#[test]
fn prop_arrangement_partitions_population() {
    forall("arrangement partitions clients", 200, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..40);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let pos = rng.sample_distinct(cc, dims);
        let arr = Arrangement::from_position(spec, &pos, cc);
        // Aggregators ∪ trainers = population, no overlap.
        let mut seen = vec![0u8; cc];
        for &c in &arr.aggregators {
            seen[c] += 1;
        }
        for c in arr.all_trainers() {
            seen[c] += 1;
        }
        assert!(seen.iter().all(|&n| n == 1), "partition violated");
        // role_of agrees.
        for c in 0..cc {
            match arr.role_of(c) {
                Role::Aggregator { slot } => assert_eq!(arr.aggregators[slot], c),
                Role::Trainer { parent_slot } => {
                    assert!(arr.buffer_of(parent_slot).contains(&c))
                }
                Role::Idle => panic!("client {c} idle in full arrangement"),
            }
        }
    });
}

#[test]
fn prop_tpd_positive_and_bounded() {
    forall("tpd positive and level-bounded", 150, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..30);
        let attrs = random_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let pos = rng.sample_distinct(cc, dims);
        let arr = Arrangement::from_position(spec, &pos, cc);
        let b = tpd(&arr, &attrs);
        assert!(b.total > 0.0);
        assert_eq!(b.level_max.len(), spec.depth);
        // Total is the sum of level maxima.
        assert!((b.level_max.iter().sum::<f64>() - b.total).abs() < 1e-9);
        // Memory-penalized TPD with penalty 1 is identical; ≥ with more.
        assert_eq!(tpd_with_memory(&arr, &attrs, 1.0), b);
        assert!(tpd_with_memory(&arr, &attrs, 3.0).total >= b.total - 1e-12);
    });
}

#[test]
fn prop_tpd_swapping_fast_root_helps() {
    forall("faster root never hurts", 100, |g| {
        let spec = HierarchySpec::new(2, 2);
        let cc = 3 + g.usize_in(1..20);
        let mut attrs = random_population(g, cc);
        // Make client 0 the slowest, client cc-1 the fastest.
        attrs[0].pspeed = 5.0;
        attrs[cc - 1].pspeed = 15.0;
        let slow = tpd(&Arrangement::from_position(spec, &[0, 1, 2], cc), &attrs);
        let fast = tpd(
            &Arrangement::from_position(spec, &[cc - 1, 1, 2], cc),
            &attrs,
        );
        assert!(fast.total <= slow.total + 1e-9);
    });
}

#[test]
fn prop_event_driven_round_conforms_across_shapes() {
    // For every hierarchy shape: the free-network, static, level-barrier
    // discrete-event round equals the closed-form Eq. 6–7 TPD, and the
    // pipelined mode is never slower.
    forall("des round matches analytic TPD", 80, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..30);
        let attrs = random_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let pos = rng.sample_distinct(cc, dims);
        let arr = Arrangement::from_position(spec, &pos, cc);
        let expect = tpd(&arr, &attrs).total;
        let net = NetworkModel::zero_cost(cc);
        let real = RoundRealization::all_on(cc, 0);
        let barrier = simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::LevelBarrier);
        assert!(
            (barrier.tpd - expect).abs() < 1e-9,
            "des {} != analytic {expect}",
            barrier.tpd
        );
        let piped = simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::Pipelined);
        assert!(piped.tpd <= barrier.tpd + 1e-12);
        assert!(piped.events > 0 && barrier.events > 0);
    });
}

/// Randomized dynamics spec exercising every mechanism, including the
/// correlated-failure and partition state machines.
fn random_dynamics_spec(g: &mut Gen) -> DynamicsSpec {
    DynamicsSpec {
        dropout_prob: g.f64_in(0.0, 0.5),
        churn_leave_prob: g.f64_in(0.0, 0.5),
        churn_join_prob: g.f64_in(0.0, 0.8),
        straggler_prob: g.f64_in(0.0, 0.8),
        straggler_frac: g.f64_in(0.0, 1.0),
        straggler_slowdown: 1.0 + g.f64_in(0.0, 4.0),
        drift_sigma: g.f64_in(0.0, 0.3),
        corr_fail_prob: g.f64_in(0.0, 0.6),
        corr_fail_frac: g.f64_in(0.01, 0.6),
        partition_prob: g.f64_in(0.0, 0.5),
        partition_frac: g.f64_in(0.01, 0.6),
        partition_rounds: 1 + g.usize_in(0..4),
    }
}

#[test]
fn prop_dynamics_live_count_stays_within_population_bounds() {
    // Churn (and every failure mechanism stacked on top) never drives
    // the live-client count below 1 or above n.
    forall("dynamics live-count bounds", 120, |g| {
        let spec = random_dynamics_spec(g);
        let n = 1 + g.usize_in(0..60);
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(g.u64_in(0..1 << 40)));
        for _ in 0..25 {
            let r = d.next_round(n);
            assert_eq!(r.active.len(), n);
            assert_eq!(r.slowdown.len(), n);
            let live = r.active.iter().filter(|&&a| a).count();
            assert!((1..=n).contains(&live), "live {live} outside [1, {n}]");
            assert!(r.slowdown.iter().all(|&s| s.is_finite() && s > 0.0));
        }
    });
}

#[test]
fn prop_dynamics_same_seed_identical_realization_sequence() {
    forall("dynamics same-seed determinism", 80, |g| {
        let spec = random_dynamics_spec(g);
        let seed = g.u64_in(0..1 << 40);
        let n = 2 + g.usize_in(0..40);
        let mut a = Dynamics::new(spec.clone(), Pcg32::seed_from_u64(seed));
        let mut b = Dynamics::new(spec, Pcg32::seed_from_u64(seed));
        for _ in 0..15 {
            assert_eq!(a.next_round(n), b.next_round(n));
        }
    });
}

#[test]
fn prop_realizations_shared_across_an_eval_batch() {
    // Inside one eval_batch every placement is scored under the same
    // realization and the same per-eval jitter stream: identical
    // placements in a batch must score identically, whatever dynamics
    // are active.
    use repro::configio::SimScenario;
    use repro::des::EventDrivenEnv;
    use repro::placement::Environment;
    forall("batch shares one realization", 40, |g| {
        let mut sc = SimScenario {
            depth: 1 + g.usize_in(0..3),
            width: 1 + g.usize_in(0..3),
            env: "event-driven".into(),
            ..SimScenario::default()
        };
        sc.seed = g.u64_in(0..1 << 40);
        sc.des.train_unit = g.f64_in(0.0, 2.0);
        sc.des.net.jitter_sigma = g.f64_in(0.0, 0.5);
        sc.des.dynamics = random_dynamics_spec(g);
        let cc = sc.client_count();
        let spec = HierarchySpec::new(sc.depth, sc.width);
        let attrs = random_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..1 << 40));
        let p = Placement::new(rng.sample_distinct(cc, spec.dimensions()));
        let q = Placement::new(rng.sample_distinct(cc, spec.dimensions()));
        let mut env = EventDrivenEnv::from_scenario(&sc, attrs);
        for _ in 0..4 {
            let batch = vec![p.clone(), q.clone(), p.clone()];
            let delays = env.eval_batch(&batch).unwrap();
            assert_eq!(delays[0], delays[2], "same placement, same batch, same score");
        }
    });
}

#[test]
fn prop_failure_mechanisms_never_orphan_a_serving_aggregator() {
    // Correlated failures and partitions only silence clients *assigned
    // as trainers*; aggregator slots always serve. Consequently every
    // round completes: the root aggregation fires (simulate_round would
    // hit unreachable!() on a drained queue otherwise) with a finite,
    // positive TPD, no matter how hard the failure mechanisms hit.
    forall("corrfail/partition rounds always complete", 60, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..30);
        let attrs = random_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let pos = rng.sample_distinct(cc, dims);
        let arr = Arrangement::from_position(spec, &pos, cc);
        let net = NetworkModel::zero_cost(cc);
        let mut dyn_spec = random_dynamics_spec(g);
        // Bias hard toward the new mechanisms, up to total blackout.
        dyn_spec.corr_fail_prob = g.f64_in(0.5, 1.0);
        dyn_spec.corr_fail_frac = g.f64_in(0.5, 1.0);
        dyn_spec.partition_prob = g.f64_in(0.5, 1.0);
        dyn_spec.partition_frac = g.f64_in(0.5, 1.0);
        let mut d = Dynamics::new(dyn_spec, Pcg32::seed_from_u64(g.u64_in(0..1 << 40)));
        for _ in 0..8 {
            let real = d.next_round(cc);
            let out = simulate_round(&arr, &attrs, &net, &real, 1.0, SyncMode::LevelBarrier);
            assert!(out.tpd.is_finite() && out.tpd > 0.0, "tpd {}", out.tpd);
            assert!(out.dropped_trainers <= cc - dims);
            let piped = simulate_round(&arr, &attrs, &net, &real, 1.0, SyncMode::Pipelined);
            assert!(piped.tpd <= out.tpd + 1e-12);
        }
    });
}

#[test]
fn prop_validator_fallback_path_beyond_word_size() {
    // client_count > 64 always takes the Vec<bool> branch of
    // `validate_placement` — only the u64-bitmask fast path runs at
    // paper scale, so exercise every error class here.
    forall("validate_placement >64-client fallback", 200, |g| {
        let cc = 65 + g.usize_in(0..400);
        let dims = 1 + g.usize_in(0..40);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..1 << 40));
        let pos = rng.sample_distinct(cc, dims);
        assert_eq!(validate_placement(&pos, dims, cc), Ok(()));
        if dims >= 2 {
            let mut dup = pos.clone();
            dup[dims - 1] = dup[0];
            assert_eq!(
                validate_placement(&dup, dims, cc),
                Err(PlacementError::DuplicateClient { client: dup[0] })
            );
        }
        let mut oob = pos.clone();
        oob[dims - 1] = cc + g.usize_in(0..10);
        assert_eq!(
            validate_placement(&oob, dims, cc),
            Err(PlacementError::ClientOutOfRange { client: oob[dims - 1], client_count: cc })
        );
        assert_eq!(
            validate_placement(&pos[..dims - 1], dims, cc),
            Err(PlacementError::WrongArity { expected: dims, got: dims - 1 })
        );
    });
}

#[test]
fn prop_validator_paths_agree_on_shared_domain() {
    // Any placement over ids < 64 can be validated by both branches
    // (bitmask at cc = 64, fallback at cc > 64); verdicts — including
    // which duplicate is reported first — must be identical.
    forall("bitmask and fallback validators agree", 300, |g| {
        let dims = 1 + g.usize_in(0..12);
        let p: Vec<usize> = (0..dims).map(|_| g.usize_in(0..64)).collect();
        let bitmask = validate_placement(&p, dims, 64);
        let fallback = validate_placement(&p, dims, 65 + g.usize_in(0..200));
        assert_eq!(bitmask, fallback, "paths disagree on {p:?}");
    });
}

#[test]
fn prop_swarm_gbest_monotone_and_valid() {
    forall("swarm invariants", 60, |g| {
        let dims = g.usize_in(1..8);
        let cc = dims + g.usize_in(1..20);
        let cfg = PsoConfig {
            particles: g.usize_in(2..8),
            iterations: 30,
            ..PsoConfig::paper()
        };
        let mut swarm = Swarm::new(dims, cc, cfg, Pcg32::seed_from_u64(g.u64_in(0..1 << 40)));
        let stats = swarm.run(|pos| pos.iter().sum::<usize>() as f64 + 1.0);
        for w in stats.windows(2) {
            assert!(w[1].gbest_tpd <= w[0].gbest_tpd + 1e-12);
        }
        let gp = swarm.gbest_placement();
        let mut s = gp.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), dims);
        assert!(gp.iter().all(|&c| c < cc));
    });
}

#[test]
fn prop_async_swarm_gbest_equals_min_observed() {
    forall("async swarm tracks min", 60, |g| {
        let dims = g.usize_in(1..6);
        let cc = dims + g.usize_in(1..15);
        let mut swarm = AsyncSwarm::new(
            dims,
            cc,
            PsoConfig::paper(),
            Pcg32::seed_from_u64(g.u64_in(0..1 << 40)),
        );
        let mut min = f64::INFINITY;
        for _ in 0..g.usize_in(5..60) {
            let p = swarm.propose();
            let d = p.iter().map(|&c| (c + 1) as f64).sum::<f64>();
            // Once pinned, reports don't change gbest; min only tracks
            // pre-pin observations.
            if !swarm.pinned() {
                min = min.min(d);
            }
            swarm.report(d);
        }
        if min.is_finite() {
            assert!((swarm.gbest_delay() - min).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_strategies_always_valid() {
    // Every registry strategy (including tabu and adaptive-pso), driven
    // through the Stepwise adapter over the batched Optimizer protocol.
    forall("strategies propose valid placements", 40, |g| {
        let dims = g.usize_in(1..6);
        let cc = dims + g.usize_in(1..15);
        let seed = g.u64_in(0..1 << 40);
        for name in registry::NAMES {
            let opt = registry::build_live(name, dims, cc, PsoConfig::paper(), seed)
                .unwrap_or_else(|e| panic!("build {name}: {e}"));
            let mut s = Stepwise::new(opt);
            for round in 0..30 {
                let p = s.propose(round);
                assert_valid_placement(&p, dims, cc);
                s.feedback((round % 7) as f64 + 0.5);
            }
        }
    });
}

#[test]
fn prop_registry_rejects_unknown_names() {
    forall("registry errors are actionable", 20, |g| {
        let bogus = format!("strategy-{}", g.usize_in(0..1000));
        let err = registry::build_live(&bogus, 2, 5, PsoConfig::paper(), 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&bogus));
        assert!(msg.contains("round-robin"), "should list valid names: {msg}");
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    fn arb_value(g: &mut Gen, depth: usize) -> Value {
        match if depth > 2 { g.usize_in(0..4) } else { g.usize_in(0..6) } {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Num((g.f64_in(-1e9, 1e9) * 1e6).round() / 1e6),
            3 => {
                let n = g.usize_in(0..12);
                Value::Str((0..n).map(|_| char::from(g.usize_in(32..127) as u8)).collect())
            }
            4 => Value::Array((0..g.usize_in(0..5)).map(|_| arb_value(g, depth + 1)).collect()),
            _ => Value::Object(
                (0..g.usize_in(0..5))
                    .map(|i| (format!("k{i}"), arb_value(g, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall("json roundtrip", 300, |g| {
        let v = arb_value(g, 0);
        let text = json::to_string(&v);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("reparse {text:?}: {e}"));
        assert_eq!(back, v);
    });
}

#[test]
fn prop_model_codec_roundtrip() {
    forall("model codec roundtrip", 120, |g| {
        let n = g.usize_in(0..2000);
        let params: Vec<f32> = (0..n).map(|_| g.f64_in(-10.0, 10.0) as f32).collect();
        let update = ModelUpdate {
            sender: g.usize_in(0..1000),
            weight: g.f64_in(0.1, 1e6) as f32,
            params,
        };
        // Binary: bit exact.
        let bin = ModelCodec::decode(&ModelCodec::Binary.encode(&update)).unwrap();
        assert_eq!(bin, update);
        // JSON: close.
        let js = ModelCodec::decode(&ModelCodec::Json.encode(&update)).unwrap();
        assert_eq!(js.sender, update.sender);
        assert_eq!(js.params.len(), update.params.len());
        for (a, b) in update.params.iter().zip(&js.params) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0));
        }
    });
}

#[test]
fn prop_topic_matching_reflexive_and_wildcards() {
    forall("topic matching", 200, |g| {
        use repro::broker::topic_matches;
        let n = g.usize_in(1..5);
        let levels: Vec<String> = (0..n).map(|i| format!("l{}{}", i, g.usize_in(0..5))).collect();
        let topic = levels.join("/");
        // Exact self-match.
        assert!(topic_matches(&topic, &topic));
        // Replacing any one level with '+' still matches.
        let k = g.usize_in(0..n);
        let mut f = levels.clone();
        f[k] = "+".into();
        assert!(topic_matches(&f.join("/"), &topic));
        // '#' prefix matches.
        if n >= 2 {
            let prefix = levels[..n - 1].join("/") + "/#";
            assert!(topic_matches(&prefix, &topic));
        }
        // A different first level never matches.
        let mut g2 = levels.clone();
        g2[0] = "ZZZ".into();
        assert!(!topic_matches(&g2.join("/"), &topic));
    });
}

#[test]
fn prop_round_robin_uniform_duty() {
    forall("round robin uniform duty", 80, |g| {
        let dims = g.usize_in(1..5);
        let cc = dims + g.usize_in(0..12) + 1;
        let mut s = RoundRobinPlacement::new(dims, cc);
        let mut count = vec![0usize; cc];
        // One full cycle of cc rounds covers each client dims times.
        for r in 0..cc {
            for &c in s.propose_batch(r).pop().unwrap().iter() {
                count[c] += 1;
            }
        }
        assert!(
            count.iter().all(|&n| n == dims),
            "uneven duty: {count:?} (dims {dims}, cc {cc})"
        );
    });
}

/// Population with per-client-distinct mdatasize, so a wrong trainer
/// partition cannot hide behind uniform data sizes.
fn random_hetero_population(g: &mut Gen, n: usize) -> Vec<ClientAttrs> {
    let mut attrs = random_population(g, n);
    let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
    for a in attrs.iter_mut() {
        a.mdatasize = rng.uniform(1.0, 9.0);
    }
    attrs
}

#[test]
fn prop_scratch_eval_bit_identical_to_legacy_tpd() {
    // The zero-allocation streaming evaluation must equal the legacy
    // Arrangement pipeline bit for bit — across random shapes and
    // populations, including >64-client ones that exercise the word
    // bitset past the validate_placement u64 fast path.
    forall("scratch tpd == legacy tpd (bitwise)", 150, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..100);
        let attrs = random_hetero_population(g, cc);
        let mut scratch = TpdScratch::new(spec, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        for _ in 0..4 {
            let pos = rng.sample_distinct(cc, dims);
            let fast = scratch.eval(&pos, &attrs).unwrap();
            let slow = tpd(&Arrangement::from_position(spec, &pos, cc), &attrs).total;
            assert_eq!(fast.to_bits(), slow.to_bits(), "{fast} != {slow} at {pos:?}");
        }
    });
}

#[test]
fn prop_delta_evaluations_bit_identical_to_full_eval() {
    // One-swap delta paths (single-slot replacement and two-slot swap)
    // must reproduce a from-scratch evaluation of the neighbor bitwise,
    // and must leave the cached base untouched.
    forall("delta eval == full eval (bitwise)", 120, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + 1 + g.usize_in(0..90); // at least one free client
        let attrs = random_hetero_population(g, cc);
        let mut scratch = TpdScratch::new(spec, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let pos = rng.sample_distinct(cc, dims);
        let base_total = scratch.eval(&pos, &attrs).unwrap();
        for _ in 0..4 {
            // Replacement neighbor.
            let k = rng.gen_range(dims as u64) as usize;
            let mut b = rng.gen_range(cc as u64) as usize;
            while pos.contains(&b) {
                b = (b + 1) % cc;
            }
            let mut neighbor = pos.clone();
            neighbor[k] = b;
            let fast = scratch.delta_replace(k, b, &attrs);
            let slow = tpd(&Arrangement::from_position(spec, &neighbor, cc), &attrs).total;
            assert_eq!(
                fast.to_bits(),
                slow.to_bits(),
                "replace slot {k}: {} -> {b} on {pos:?}",
                pos[k]
            );
            // Swap neighbor (needs two slots).
            if dims >= 2 {
                let i = rng.gen_range(dims as u64) as usize;
                let mut j = rng.gen_range(dims as u64) as usize;
                while j == i {
                    j = rng.gen_range(dims as u64) as usize;
                }
                let mut swapped = pos.clone();
                swapped.swap(i, j);
                let fast = scratch.delta_swap(i, j, &attrs);
                let slow = tpd(&Arrangement::from_position(spec, &swapped, cc), &attrs).total;
                assert_eq!(fast.to_bits(), slow.to_bits(), "swap {i}<->{j} on {pos:?}");
            }
            // Excursions never disturb the cached base.
            assert_eq!(scratch.total().to_bits(), base_total.to_bits());
            assert_eq!(scratch.position(), &pos[..]);
        }
    });
}

#[test]
fn prop_scratch_view_partition_matches_from_position() {
    forall("EvalScratch partition == Arrangement trainers", 120, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..100);
        let mut view = EvalScratch::new(spec, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let pos = rng.sample_distinct(cc, dims);
        view.load(&pos).unwrap();
        let arr = Arrangement::from_position(spec, &pos, cc);
        for i in 0..view.leaf_count() {
            assert_eq!(view.leaf_trainers(i), &arr.trainers[i][..], "leaf {i}");
        }
        for c in 0..cc {
            assert_eq!(view.is_aggregator(c), pos.contains(&c), "client {c}");
        }
    });
}

#[test]
fn prop_scratch_round_bit_identical_to_reference_round() {
    // The reusable RoundScratch must reproduce simulate_round exactly —
    // tpd bits, event count, dropped trainers — under jitter, network
    // contention, dropouts and slowdowns, with the scratch reused
    // across many candidates (stale-state bugs would surface here).
    forall("RoundScratch == simulate_round (bitwise)", 80, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..40);
        let attrs = random_hetero_population(g, cc);
        let mut net = NetworkModel::zero_cost(cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        for l in net.uplinks.iter_mut() {
            l.latency_s = rng.uniform(0.0, 0.05);
            l.bandwidth = rng.uniform(5.0, 50.0);
        }
        if g.bool() {
            net.agg_ingress = rng.uniform(10.0, 100.0);
        }
        net.jitter_sigma = g.f64_in(0.0, 0.5);
        let train_unit = g.f64_in(0.0, 2.0);
        let mode = if g.bool() { SyncMode::LevelBarrier } else { SyncMode::Pipelined };
        let mut scratch = RoundScratch::new(spec, cc);
        for round in 0..4 {
            let mut real = RoundRealization::all_on(cc, rng.next_u64());
            for a in real.active.iter_mut() {
                *a = rng.next_f64() > 0.25;
            }
            for s in real.slowdown.iter_mut() {
                *s = rng.uniform(1.0, 3.0);
            }
            let pos = rng.sample_distinct(cc, dims);
            let arr = Arrangement::from_position(spec, &pos, cc);
            let want = simulate_round(&arr, &attrs, &net, &real, train_unit, mode);
            let got = scratch.simulate(&pos, &attrs, &net, &real, train_unit, mode).unwrap();
            assert_eq!(got.tpd.to_bits(), want.tpd.to_bits(), "round {round}: {got:?} {want:?}");
            assert_eq!(got.events, want.events);
            assert_eq!(got.dropped_trainers, want.dropped_trainers);
        }
    });
}

#[test]
fn prop_event_env_scores_equal_reference_rounds() {
    // End-to-end: EventDrivenEnv (scratch-backed) must score each batch
    // element exactly as a reference simulate_round over the same
    // realization, network and jitter seed would.
    use repro::configio::SimScenario;
    forall("EventDrivenEnv == reference rounds", 40, |g| {
        let mut sc = SimScenario {
            depth: 1 + g.usize_in(0..3),
            width: 1 + g.usize_in(0..3),
            env: "event-driven".into(),
            ..SimScenario::default()
        };
        sc.seed = g.u64_in(0..1 << 40);
        sc.des.train_unit = g.f64_in(0.0, 2.0);
        sc.des.net.latency_range_s = (0.001, 0.03);
        sc.des.net.bandwidth_range = (5.0, 50.0);
        sc.des.net.jitter_sigma = g.f64_in(0.0, 0.5);
        sc.des.dynamics = random_dynamics_spec(g);
        let cc = sc.client_count();
        let spec = HierarchySpec::new(sc.depth, sc.width);
        let attrs = random_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..1 << 40));
        let batch: Vec<Placement> =
            (0..3).map(|_| Placement::new(rng.sample_distinct(cc, spec.dimensions()))).collect();
        let mut env = EventDrivenEnv::from_scenario(&sc, attrs.clone());
        for _ in 0..3 {
            let real = env.realization().clone();
            let delays = env.eval_batch(&batch).unwrap();
            for (p, &d) in batch.iter().zip(&delays) {
                let arr = Arrangement::from_position(spec, p, cc);
                let want = simulate_round(
                    &arr,
                    &attrs,
                    env.net(),
                    &real,
                    env.train_unit(),
                    env.sync_mode(),
                );
                assert_eq!(d.to_bits(), want.tpd.to_bits());
            }
        }
    });
}

#[test]
fn prop_roles_one_pass_agrees_with_role_of() {
    forall("roles() == role_of() per client", 120, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + g.usize_in(0..100);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let pos = rng.sample_distinct(cc, dims);
        let arr = Arrangement::from_position(spec, &pos, cc);
        let roles = arr.roles();
        assert_eq!(roles.len(), cc);
        let mut aggs = 0;
        let mut trainers = 0;
        for (c, &r) in roles.iter().enumerate() {
            assert_eq!(r, arr.role_of(c), "client {c}");
            match r {
                Role::Aggregator { slot } => {
                    aggs += 1;
                    assert_eq!(arr.aggregators[slot], c);
                }
                Role::Trainer { parent_slot } => {
                    trainers += 1;
                    assert!(arr.buffer_of(parent_slot).contains(&c));
                }
                Role::Idle => panic!("client {c} idle in full arrangement"),
            }
        }
        assert_eq!(aggs, dims);
        assert_eq!(trainers, cc - dims);
        // Out-of-population clients are Idle.
        assert_eq!(arr.role_of(cc + g.usize_in(0..10)), Role::Idle);
    });
}

#[test]
fn prop_spec_closed_forms_match_reference_series() {
    // The O(1) closed forms (dimensions, level_start, level_of,
    // children-as-range) must agree with the defining geometric series
    // on every random shape, width 1 included.
    forall("spec closed forms == series", 150, |g| {
        let spec = random_spec(g);
        let series: usize = (0..spec.depth).map(|i| spec.width.pow(i as u32)).sum();
        assert_eq!(spec.dimensions(), series);
        let mut start = 0usize;
        let mut size = 1usize;
        for l in 0..spec.depth {
            assert_eq!(spec.level_start(l), start, "level_start({l})");
            for s in spec.level_slots(l) {
                assert_eq!(spec.level_of(s), l, "level_of({s})");
            }
            start += size;
            size *= spec.width;
        }
        for s in 0..spec.dimensions() {
            let first = s * spec.width + 1;
            let reference: Vec<usize> =
                (first..first + spec.width).filter(|&c| c < series).collect();
            assert_eq!(spec.children(s).collect::<Vec<_>>(), reference, "children({s})");
        }
    });
}

#[test]
fn prop_sharded_eval_batch_bit_identical_to_serial() {
    // ParEvalBatch must reproduce the serial environment bit for bit at
    // any worker count: across random shapes, neighbor-rich batches
    // (hitting the same/delta/full scoring paths across shard
    // boundaries), and successive batches of a *dynamic* DES scenario
    // (every worker's round stream stays in lockstep because all
    // workers are dispatched on every batch, empty chunks included).
    use repro::configio::SimScenario;
    forall("sharded eval_batch == serial", 25, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + 1 + g.usize_in(0..30);
        let attrs = random_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let mut batch = vec![Placement::new(rng.sample_distinct(cc, dims))];
        for _ in 0..g.usize_in(4..24) {
            let prev: Vec<usize> = batch.last().unwrap().to_vec();
            let mut next = prev.clone();
            match rng.gen_range(4) {
                0 => next = rng.sample_distinct(cc, dims),
                1 => {
                    let (slot, id) = draw_slot_replacement(&prev, cc, &mut rng);
                    next[slot] = id;
                }
                2 if dims >= 2 => {
                    let i = rng.gen_range(dims as u64) as usize;
                    let j = (i + 1 + rng.gen_range(dims as u64 - 1) as usize) % dims;
                    next.swap(i, j);
                }
                _ => {} // duplicate of the predecessor: the Same path
            }
            batch.push(Placement::new(next));
        }
        let bits = |v: Vec<f64>| -> Vec<u64> { v.iter().map(|d| d.to_bits()).collect() };
        let mut serial = AnalyticTpd::new(spec, attrs.clone());
        let want = bits(serial.eval_batch(&batch).unwrap());
        for threads in [1usize, 2, 8] {
            let mut par = ParEvalBatch::new(threads, |_| AnalyticTpd::new(spec, attrs.clone()));
            let got = bits(par.eval_batch(&batch).unwrap());
            assert_eq!(got, want, "analytic, threads={threads}");
        }
        // Dynamic DES scenario: jitter, dropouts and stragglers, three
        // rounds of batches with single evals interleaved.
        let mut sc = SimScenario { depth: spec.depth, width: spec.width, ..SimScenario::default() };
        sc.seed = g.u64_in(0..1_000_000);
        sc.des.train_unit = 1.0;
        sc.des.net.latency_range_s = (0.001, 0.02);
        sc.des.net.bandwidth_range = (5.0, 50.0);
        sc.des.net.jitter_sigma = 0.3;
        sc.des.dynamics.dropout_prob = 0.2;
        sc.des.dynamics.straggler_prob = 0.3;
        sc.des.dynamics.straggler_frac = 0.2;
        sc.des.dynamics.straggler_slowdown = 3.0;
        let mut serial_des = EventDrivenEnv::from_scenario(&sc, attrs.clone());
        let mut par_des =
            ParEvalBatch::new(3, |_| EventDrivenEnv::from_scenario(&sc, attrs.clone()));
        for round in 0..3 {
            let want = bits(serial_des.eval_batch(&batch).unwrap());
            let got = bits(par_des.eval_batch(&batch).unwrap());
            assert_eq!(got, want, "des round {round}");
            let w = serial_des.eval(&batch[0]).unwrap();
            let p = par_des.eval(&batch[0]).unwrap();
            assert_eq!(p.to_bits(), w.to_bits(), "des single round {round}");
        }
    });
}

#[test]
fn prop_chunked_fold_is_pure_function_of_stream_and_tracks_linear() {
    // The fixed-shape 8-lane pairwise fold shared by every per-leaf sum
    // (legacy tpd, TpdScratch full + delta, DES rounds, sharded
    // workers): one-shot == streaming == re-run bitwise (a pure
    // function of the element sequence), exactly the legacy left fold
    // for short streams, and within float noise of it for long ones —
    // the legacy `linear_sum` stays callable as the reference oracle.
    use repro::fitness::{linear_sum, ChunkedFold8};
    forall("chunked fold contract", 250, |g| {
        let n = g.usize_in(0..300); // spans many full lane cycles
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.001, 9.0)).collect();
        let one_shot = ChunkedFold8::sum(xs.iter().copied());
        let mut streaming = ChunkedFold8::new();
        for &x in &xs {
            streaming.push(x);
        }
        assert_eq!(one_shot.to_bits(), streaming.finish().to_bits());
        assert_eq!(one_shot.to_bits(), ChunkedFold8::sum(xs.iter().copied()).to_bits());
        let linear = linear_sum(xs.iter().copied());
        if n <= 3 {
            // Fewer pushes than any cross-lane pairing: exactly linear.
            assert_eq!(one_shot.to_bits(), linear.to_bits());
        } else {
            assert!(
                (one_shot - linear).abs() <= 1e-12 * linear.abs().max(1.0),
                "chunked {one_shot} vs linear {linear} at n={n}"
            );
        }
    });
}

#[test]
fn prop_fold_order_identical_across_full_delta_and_sharded_paths() {
    // The fold-order contract end to end: full streaming eval, legacy
    // arrangement pipeline, delta fast paths and the sharded worker
    // pool all stream per-leaf sums in the same fixed order, so their
    // scores are bit-identical — on random shapes over populations
    // always past the 64-client validator fast path.
    forall("fold order across eval paths", 60, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + 66 + g.usize_in(0..120); // > 64 clients, free ids left
        let attrs = random_hetero_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let base = rng.sample_distinct(cc, dims);
        let mut scratch = TpdScratch::new(spec, cc);
        let full = scratch.eval(&base, &attrs).unwrap();
        let legacy = tpd(&Arrangement::from_position(spec, &base, cc), &attrs).total;
        assert_eq!(full.to_bits(), legacy.to_bits());
        // Replace-delta against a fresh full eval of the neighbor.
        let (slot, id) = draw_slot_replacement(&base, cc, &mut rng);
        let mut neighbor = base.clone();
        neighbor[slot] = id;
        let delta = scratch.delta_replace(slot, id, &attrs);
        let fresh = TpdScratch::new(spec, cc).eval(&neighbor, &attrs).unwrap();
        assert_eq!(delta.to_bits(), fresh.to_bits());
        // Sharded pool scores the same candidates with the same bits.
        let batch = vec![Placement::new(base), Placement::new(neighbor)];
        let mut serial = AnalyticTpd::new(spec, attrs.clone());
        let want: Vec<u64> =
            serial.eval_batch(&batch).unwrap().iter().map(|d| d.to_bits()).collect();
        assert_eq!(want[0], full.to_bits());
        assert_eq!(want[1], delta.to_bits());
        for threads in [2usize, 8] {
            let mut par = ParEvalBatch::new(threads, |_| AnalyticTpd::new(spec, attrs.clone()));
            let got: Vec<u64> =
                par.eval_batch(&batch).unwrap().iter().map(|d| d.to_bits()).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    });
}

#[test]
fn prop_sharded_pso_search_is_thread_count_invariant() {
    // The tentpole determinism claim: a ShardedPso run — proposals,
    // exchanges and the final composed best — is a pure function of the
    // seed and the observed delays. Since the oracles are bit-exact at
    // any worker count, driving against ParEvalBatch at 1, 2 and 8
    // threads must finish with the same best placement, bit-identical
    // delay included, as the serial environment.
    forall("sharded-pso invariant across thread counts", 12, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + 1 + g.usize_in(0..40);
        let attrs = random_population(g, cc);
        let seed = g.u64_in(0..1 << 40);
        let budget = 40 + g.usize_in(0..80);
        let cfg = ShardedConfig {
            particles: 2 + g.usize_in(0..8),
            exchange_every: 1 + g.usize_in(0..4),
        };
        let mut run = |env: &mut dyn Environment| -> (Vec<usize>, u64) {
            let mut opt = ShardedPso::from_spec(spec, cc, cfg, Pcg32::seed_from_u64(seed));
            drive(&mut opt, env, budget).unwrap();
            let (p, d) = opt.best().expect("budget > 0 observed something");
            assert_valid_placement(&p, dims, cc);
            (p.into_vec(), d.to_bits())
        };
        let want = run(&mut AnalyticTpd::new(spec, attrs.clone()));
        for threads in [1usize, 2, 8] {
            let mut par = ParEvalBatch::new(threads, |_| AnalyticTpd::new(spec, attrs.clone()));
            let got = run(&mut par);
            assert_eq!(got, want, "threads={threads}");
        }
    });
}

#[test]
fn prop_des_barrier_delta_matches_full_simulation() {
    // In the statically-analyzable regime (level barrier, free network,
    // no training, nominal realization) the EventDrivenEnv delta fast
    // path must reproduce a fresh env's full event-loop simulation bit
    // for bit for every replace/swap neighbor, at any shape — and must
    // fire no events doing it.
    forall("des barrier delta == full sim", 40, |g| {
        let spec = random_spec(g);
        let dims = spec.dimensions();
        let cc = dims + 1 + g.usize_in(0..20);
        let attrs = random_population(g, cc);
        let mut rng = Pcg32::seed_from_u64(g.u64_in(0..u64::MAX / 2));
        let base = Placement::new(rng.sample_distinct(cc, dims));
        let mut env = EventDrivenEnv::conformance(spec, attrs.clone());
        env.eval(&base).unwrap();
        let fired = env.events_fired;
        for _ in 0..6 {
            let mut n: Vec<usize> = base.to_vec();
            if g.bool() && dims >= 2 {
                let i = rng.gen_range(dims as u64) as usize;
                let j = (i + 1 + rng.gen_range(dims as u64 - 1) as usize) % dims;
                n.swap(i, j);
            } else {
                let (slot, id) = draw_slot_replacement(&base, cc, &mut rng);
                n[slot] = id;
            }
            let n = Placement::new(n);
            let got = env.eval(&n).unwrap();
            let want = EventDrivenEnv::conformance(spec, attrs.clone()).eval(&n).unwrap();
            assert_eq!(got.to_bits(), want.to_bits());
        }
        assert_eq!(env.events_fired, fired, "neighbors must not re-simulate");
    });
}
