//! Broker integration: in-process and TCP transports, concurrency,
//! retained semantics, large payloads.

use repro::broker::{Broker, TcpBrokerServer, TcpClient};
use std::time::Duration;

#[test]
fn inproc_fanout_to_many_subscribers() {
    let broker = Broker::new();
    let mut subs: Vec<_> = (0..20)
        .map(|i| {
            let mut c = broker.connect(&format!("sub{i}"));
            c.subscribe("bench/topic").unwrap();
            c
        })
        .collect();
    let publisher = broker.connect("pub");
    publisher.publish("bench/topic", vec![7u8; 1024]).unwrap();
    for s in &mut subs {
        let m = s.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.payload.len(), 1024);
    }
}

#[test]
fn inproc_many_publishers_one_subscriber() {
    let broker = Broker::new();
    let mut sub = broker.connect("sub");
    sub.subscribe("w/+").unwrap();
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let b = broker.clone();
            std::thread::spawn(move || {
                let c = b.connect(&format!("p{t}"));
                for i in 0..50 {
                    c.publish(format!("w/{t}"), vec![i as u8]).unwrap();
                }
            })
        })
        .collect();
    let mut got = 0;
    while got < 400 {
        sub.recv_timeout(Duration::from_secs(2)).expect("delivery");
        got += 1;
    }
    for t in threads {
        t.join().unwrap();
    }
}

#[test]
fn large_payload_shared_delivery() {
    // A model-sized payload (7.5 MB) fans out without copying.
    let broker = Broker::new();
    let mut a = broker.connect("a");
    let mut b = broker.connect("b");
    a.subscribe("model").unwrap();
    b.subscribe("model").unwrap();
    let payload = std::sync::Arc::new(vec![1u8; 7_500_000]);
    let p = broker.connect("pub");
    p.publish_shared("model", payload.clone()).unwrap();
    let ma = a.recv_timeout(Duration::from_secs(1)).unwrap();
    let mb = b.recv_timeout(Duration::from_secs(1)).unwrap();
    assert!(std::sync::Arc::ptr_eq(&ma.payload, &payload));
    assert!(std::sync::Arc::ptr_eq(&mb.payload, &payload));
}

#[test]
fn tcp_roundtrip() {
    let broker = Broker::new();
    let server = TcpBrokerServer::start("127.0.0.1:0", broker.clone()).unwrap();
    let addr = server.addr();

    let mut sub = TcpClient::connect(&addr).unwrap();
    sub.subscribe("fl/+/x").unwrap();
    // Give the server a beat to register the subscription.
    std::thread::sleep(Duration::from_millis(100));

    let mut pub_ = TcpClient::connect(&addr).unwrap();
    pub_.publish("fl/7/x", b"hello over tcp").unwrap();

    let msg = sub.recv(Duration::from_secs(2)).unwrap();
    assert_eq!(msg.topic, "fl/7/x");
    assert_eq!(&**msg.payload, b"hello over tcp");
}

#[test]
fn tcp_bridges_to_inproc() {
    // A TCP publisher reaches an in-process subscriber and vice versa.
    let broker = Broker::new();
    let server = TcpBrokerServer::start("127.0.0.1:0", broker.clone()).unwrap();

    let mut inproc = broker.connect("inproc");
    inproc.subscribe("bridge/in").unwrap();

    let mut tcp = TcpClient::connect(&server.addr()).unwrap();
    tcp.subscribe("bridge/out").unwrap();
    std::thread::sleep(Duration::from_millis(100));

    tcp.publish("bridge/in", b"from tcp").unwrap();
    let m = inproc.recv_timeout(Duration::from_secs(2)).unwrap();
    assert_eq!(&**m.payload, b"from tcp");

    inproc.publish("bridge/out", b"from inproc".to_vec()).unwrap();
    let m = tcp.recv(Duration::from_secs(2)).unwrap();
    assert_eq!(&**m.payload, b"from inproc");
}

#[test]
fn tcp_retained_message() {
    let broker = Broker::new();
    let server = TcpBrokerServer::start("127.0.0.1:0", broker.clone()).unwrap();

    let mut pub_ = TcpClient::connect(&server.addr()).unwrap();
    pub_.publish_retained("cfg/model", b"v2").unwrap();
    std::thread::sleep(Duration::from_millis(100));

    // Late subscriber still receives it.
    let mut sub = TcpClient::connect(&server.addr()).unwrap();
    sub.subscribe("cfg/#").unwrap();
    let m = sub.recv(Duration::from_secs(2)).unwrap();
    assert_eq!(&**m.payload, b"v2");
}

#[test]
fn tcp_large_frame() {
    // A binary-coded model update (~7.5 MB) over the TCP transport.
    let broker = Broker::new();
    let server = TcpBrokerServer::start("127.0.0.1:0", broker.clone()).unwrap();

    let mut sub = TcpClient::connect(&server.addr()).unwrap();
    sub.subscribe("big").unwrap();
    std::thread::sleep(Duration::from_millis(100));

    let payload: Vec<u8> = (0..7_500_000u32).map(|i| (i % 251) as u8).collect();
    let mut pub_ = TcpClient::connect(&server.addr()).unwrap();
    pub_.publish("big", &payload).unwrap();

    let m = sub.recv(Duration::from_secs(10)).unwrap();
    assert_eq!(m.payload.len(), payload.len());
    assert_eq!(&**m.payload, &payload[..]);
}
