//! Runtime ↔ artifacts integration: the rust PJRT path must load every
//! AOT artifact and produce numerics consistent with the python oracles.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use repro::runtime::ModelRuntime;
use std::path::PathBuf;
use std::sync::OnceLock;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<&'static ModelRuntime> {
    static RT: OnceLock<Option<ModelRuntime>> = OnceLock::new();
    RT.get_or_init(|| {
        if !artifacts_dir().join("meta.json").exists() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return None;
        }
        Some(ModelRuntime::load(&artifacts_dir()).expect("loading artifacts"))
    })
    .as_ref()
}

fn fake_batch(rt: &ModelRuntime, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    use repro::prng::{Pcg32, Rng};
    let mut rng = Pcg32::seed_from_u64(seed);
    let d = rt.meta.input_dim;
    let x: Vec<f32> = (0..b * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let y: Vec<i32> = (0..b)
        .map(|_| rng.gen_range(rt.meta.num_classes as u64) as i32)
        .collect();
    (x, y)
}

#[test]
fn init_params_shape_and_determinism() {
    let Some(rt) = runtime() else { return };
    let p1 = rt.init_params([0, 42]).unwrap();
    let p2 = rt.init_params([0, 42]).unwrap();
    assert_eq!(p1.len(), rt.meta.param_count);
    assert_eq!(p1, p2, "init must be deterministic per seed");
    let p3 = rt.init_params([1, 43]).unwrap();
    assert_ne!(p1, p3, "different seeds must differ");
    // He-init sanity: non-trivial spread, no NaNs.
    assert!(p1.iter().all(|v| v.is_finite()));
    let std = {
        let mean = p1.iter().map(|&v| v as f64).sum::<f64>() / p1.len() as f64;
        (p1.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / p1.len() as f64).sqrt()
    };
    assert!(std > 0.01 && std < 0.2, "init std {std}");
}

#[test]
fn train_step_descends_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let mut params = rt.init_params([0, 7]).unwrap();
    let (x, y) = fake_batch(rt, rt.meta.train_batch, 1);
    let mut losses = Vec::new();
    for _ in 0..6 {
        let (new_params, loss) = rt.train_step(&params, &x, &y, 0.1).unwrap();
        params = new_params;
        losses.push(loss);
    }
    assert!(
        losses[5] < losses[0] * 0.5,
        "loss should halve on a fixed batch: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn train_step_initial_loss_near_log10() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params([0, 9]).unwrap();
    let (x, y) = fake_batch(rt, rt.meta.train_batch, 2);
    let (_, loss) = rt.train_step(&params, &x, &y, 0.0).unwrap();
    assert!(
        (loss - (10f32).ln()).abs() < 1.0,
        "random-init CE loss should be ≈ ln(10), got {loss}"
    );
}

#[test]
fn train_step_zero_lr_is_identity() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params([3, 4]).unwrap();
    let (x, y) = fake_batch(rt, rt.meta.train_batch, 3);
    let (new_params, _) = rt.train_step(&params, &x, &y, 0.0).unwrap();
    assert_eq!(params, new_params);
}

#[test]
fn evaluate_returns_sane_metrics() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params([5, 6]).unwrap();
    let (x, y) = fake_batch(rt, rt.meta.eval_batch, 4);
    let (loss, acc) = rt.evaluate(&params, &x, &y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn aggregate_identity_on_same_model() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params([1, 1]).unwrap();
    let out = rt.aggregate(&[&params, &params, &params], &[1.0, 1.0, 1.0]).unwrap();
    for (a, b) in params.iter().zip(&out) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn aggregate_midpoint_and_k_padding() {
    let Some(rt) = runtime() else { return };
    let a = rt.init_params([2, 2]).unwrap();
    let b: Vec<f32> = a.iter().map(|v| v + 1.0).collect();
    // K=2 exact artifact.
    let mid = rt.aggregate(&[&a, &b], &[1.0, 1.0]).unwrap();
    for i in (0..mid.len()).step_by(100_000) {
        assert!((mid[i] - (a[i] + 0.5)).abs() < 1e-4);
    }
    // K=6 → padded into the k8 artifact; zero weights are inert.
    let models = [&a[..], &b[..], &a[..], &b[..], &a[..], &b[..]];
    let w = [1.0f32, 1.0, 1.0, 1.0, 1.0, 1.0];
    let mid6 = rt.aggregate(&models, &w).unwrap();
    for i in (0..mid6.len()).step_by(100_000) {
        assert!((mid6[i] - (a[i] + 0.5)).abs() < 1e-4);
    }
}

#[test]
fn aggregate_weighted() {
    let Some(rt) = runtime() else { return };
    let a = rt.init_params([8, 8]).unwrap();
    let b: Vec<f32> = a.iter().map(|v| v + 4.0).collect();
    // weights 3:1 ⇒ out = a + 1.0
    let out = rt.aggregate(&[&a, &b], &[3.0, 1.0]).unwrap();
    for i in (0..out.len()).step_by(50_000) {
        assert!((out[i] - (a[i] + 1.0)).abs() < 1e-4);
    }
}

#[test]
fn aggregate_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params([0, 1]).unwrap();
    assert!(rt.aggregate(&[], &[]).is_err());
    assert!(rt.aggregate(&[&params], &[1.0, 2.0]).is_err());
    assert!(rt.aggregate(&[&params], &[0.0]).is_err());
    assert!(rt.aggregate(&[&params[..10]], &[1.0]).is_err());
    let nine = vec![&params[..]; 9];
    assert!(rt.aggregate(&nine, &[1.0; 9]).is_err(), "no K≥9 artifact");
}

#[test]
fn momentum_step_matches_semantics() {
    let Some(rt) = runtime() else { return };
    if !rt.has_momentum() {
        eprintln!("SKIP: momentum artifact not exported");
        return;
    }
    let params = rt.init_params([4, 4]).unwrap();
    let velocity = vec![0.0f32; params.len()];
    let (x, y) = fake_batch(rt, rt.meta.train_batch, 9);
    // mu = 0 with zero velocity must equal the plain SGD step.
    let (p_sgd, _) = rt.train_step(&params, &x, &y, 0.1).unwrap();
    let (p_mom, v_mom, _) = rt
        .train_step_momentum(&params, &velocity, &x, &y, 0.1, 0.0)
        .unwrap();
    for (i, (a, b)) in p_sgd.iter().zip(&p_mom).enumerate().step_by(100_000) {
        assert!((a - b).abs() < 1e-5, "at {i}: sgd {a} vs momentum {b}");
    }
    assert!(v_mom.iter().any(|&v| v != 0.0), "velocity should be the gradient");

    // Momentum training descends on a fixed batch.
    let mut p = params;
    let mut v = vec![0.0f32; p.len()];
    let mut losses = Vec::new();
    for _ in 0..6 {
        let (np, nv, loss) = rt.train_step_momentum(&p, &v, &x, &y, 0.05, 0.9).unwrap();
        p = np;
        v = nv;
        losses.push(loss);
    }
    assert!(losses[5] < losses[0] * 0.5, "{losses:?}");
}

#[test]
fn federated_micro_round_improves_loss() {
    // The full semantic chain: K trainers step locally from the same
    // global model on different shards; the aggregate beats the initial
    // model on every shard. This is what the SDFL framework relies on.
    let Some(rt) = runtime() else { return };
    let global = rt.init_params([0, 99]).unwrap();
    let mut locals: Vec<Vec<f32>> = Vec::new();
    let mut batches = Vec::new();
    for k in 0..3 {
        let (x, y) = fake_batch(rt, rt.meta.train_batch, 50 + k);
        let mut p = global.clone();
        for _ in 0..3 {
            let (np, _) = rt.train_step(&p, &x, &y, 0.1).unwrap();
            p = np;
        }
        locals.push(p);
        batches.push((x, y));
    }
    let refs: Vec<&[f32]> = locals.iter().map(Vec::as_slice).collect();
    let agg = rt.aggregate(&refs, &[1.0, 1.0, 1.0]).unwrap();
    for (x, y) in &batches {
        let (_, loss_before) = rt.train_step(&global, x, y, 0.0).unwrap();
        let (_, loss_after) = rt.train_step(&agg, x, y, 0.0).unwrap();
        assert!(
            loss_after < loss_before,
            "aggregated model should beat init: {loss_after} vs {loss_before}"
        );
    }
}
